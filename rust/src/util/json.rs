//! A small, complete JSON implementation (RFC 8259 subset: full syntax,
//! `\uXXXX` escapes incl. surrogate pairs, exact i64 round-trip for
//! integral numbers).
//!
//! Used for GraphSpec interchange with the python compiler, pipeline
//! save/load, and JSONL data files. Written in-tree because the build
//! environment has no vendored `serde`.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{KamaeError, Result};

/// A JSON value. Object keys keep sorted order (BTreeMap) so serialised
/// specs are deterministic — important for artifact caching in `make`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral numbers round-trip as i64 (vocabulary hashes need all 64
    /// bits of precision; f64 would corrupt them).
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ------------------------------------------------------

    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    pub fn from_str_slice(items: &[&str]) -> Json {
        Json::Array(items.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- accessors ---------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(x) => Some(*x),
            Json::Float(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(x) => Some(*x as f64),
            Json::Float(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&Vec<Json>> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup (None on missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Required-field lookup with a useful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| KamaeError::Serde(format!("missing json field: {key}")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| KamaeError::Serde(format!("field {key} is not a string")))
    }

    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.req(key)?
            .as_i64()
            .ok_or_else(|| KamaeError::Serde(format!("field {key} is not an integer")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| KamaeError::Serde(format!("field {key} is not a number")))
    }

    pub fn req_array(&self, key: &str) -> Result<&Vec<Json>> {
        self.req(key)?
            .as_array()
            .ok_or_else(|| KamaeError::Serde(format!("field {key} is not an array")))
    }

    /// Optional-field conveniences used all over transformer load().
    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn opt_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Json::as_i64)
    }

    pub fn opt_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Insert into an object (panics on non-object — construction bug).
    pub fn set<S: Into<String>, V: Into<Json>>(&mut self, key: S, value: V) -> &mut Self {
        match self {
            Json::Object(o) => {
                o.insert(key.into(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- (de)serialisation -------------------------------------------------

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, None, 0);
        out
    }

    /// Pretty serialisation (2-space indent), for specs humans read.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, Some(2), 0);
        out
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Json {
        Json::Int(x as i64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Float(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Array(v.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

// ---------------------------------------------------------------------------
// writer

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(x) => out.push_str(&x.to_string()),
        Json::Float(x) => {
            if x.is_finite() {
                // Ensure floats stay floats on re-parse ("1.0" not "1").
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{x:.1}"));
                } else {
                    out.push_str(&format!("{x}"));
                }
            } else {
                // JSON has no NaN/Inf; the python side maps null -> nan.
                out.push_str("null");
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_json(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Json::Object(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, depth + 1);
            }
            if !map.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(w * depth));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> KamaeError {
        KamaeError::Serde(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: count continuation bytes
                    let extra = match c {
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        0xF0..=0xF7 => 3,
                        _ => return Err(self.err("invalid utf-8 lead byte")),
                    };
                    let start = self.pos - 1;
                    for _ in 0..extra {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8 sequence"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        if is_float {
            text.parse::<f64>()
                .map(Json::Float)
                .map_err(|_| self.err("invalid float"))
        } else {
            // integral: prefer exact i64, fall back to f64 for huge values
            match text.parse::<i64>() {
                Ok(x) => Ok(Json::Int(x)),
                Err(_) => text
                    .parse::<f64>()
                    .map(Json::Float)
                    .map_err(|_| self.err("invalid integer")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("3.5e2").unwrap(), Json::Float(350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        assert_eq!(
            Json::parse("[1, 2, 3]").unwrap(),
            Json::Array(vec![Json::Int(1), Json::Int(2), Json::Int(3)])
        );
    }

    #[test]
    fn parse_nested_and_roundtrip() {
        let text = r#"{"a": [1, {"b": "x,y"}, null], "c": -1.25, "d": false}"#;
        let v = Json::parse(text).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn i64_precision_survives() {
        // A hash value that would lose precision through f64.
        let big = 9_007_199_254_740_993i64; // 2^53 + 1
        let v = Json::parse(&big.to_string()).unwrap();
        assert_eq!(v.as_i64(), Some(big));
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_i64(), Some(big));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair: 😀
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // raw multi-byte utf-8 passes through
        let v = Json::Str("héllo 😀".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn float_int_distinction_roundtrip() {
        let v = Json::Float(2.0);
        assert_eq!(v.to_string(), "2.0");
        assert_eq!(Json::parse("2.0").unwrap(), Json::Float(2.0));
        assert_eq!(Json::parse("2").unwrap(), Json::Int(2));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::object();
        o.set("name", "pipeline").set("stages", vec![1i64, 2]).set("ok", true);
        assert_eq!(o.req_str("name").unwrap(), "pipeline");
        assert_eq!(o.req_array("stages").unwrap().len(), 2);
        assert!(o.req("missing").is_err());
    }
}
