//! Deterministic PRNG + distributions for the synthetic data generators
//! and workload drivers (no `rand` crate in the offline vendor set).
//!
//! splitmix64 core — passes BigCrush-level mixing for our purposes and is
//! trivially seedable per partition, which keeps generation reproducible
//! under any worker-thread schedule.

/// splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free-enough variant; bias is
        // < 2^-53 for our n, acceptable for synthetic data.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal —
    /// matches the paper's "numerical values spanning many orders of
    /// magnitude" (prices, counts) that get log-transformed.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (inter-arrival times for the open-loop
    /// Poisson request driver, experiment C5).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Pick an index according to a cumulative weight table.
    pub fn pick_cdf(&mut self, cdf: &[f64]) -> usize {
        let x = self.f64() * cdf.last().copied().unwrap_or(1.0);
        match cdf.binary_search_by(|w| w.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Shuffle in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

/// Zipf sampler over ranks 1..=n with exponent s (user/item popularity in
/// the MovieLens-like generator). Precomputes the CDF once: O(n) setup,
/// O(log n) per sample.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.pick_cdf(&self.cdf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(42);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
        let x = rng.range_i64(-3, 3);
        assert!((-3..=3).contains(&x));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(3);
        let mut head = 0;
        let n = 10_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 ranks should carry far more than 1% of mass
        assert!(head > n / 20, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
