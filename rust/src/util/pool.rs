//! Scoped worker-thread helpers — the compute substrate under the
//! partitioned engine (no rayon in the offline vendor set).
//!
//! `parallel_map` fans a slice of work items over `threads` OS threads
//! using `std::thread::scope`, preserving input order in the output. Work
//! stealing is approximated with an atomic cursor over the item list,
//! which balances well when per-item cost varies (skewed partitions).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` on up to `threads` worker threads, preserving
/// order. `f` must be `Sync` (it is shared by reference across workers).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    let slots = Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // Store the result; contention is negligible because the
                // critical section is a single Vec write.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .iter_mut()
        .map(|s| s.take().expect("worker filled every slot"))
        .collect()
}

/// Default worker count: available parallelism minus one (leave a core for
/// the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        let out = parallel_map(&items, 1, |i, &x| x + i);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn skewed_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..32).map(|i| if i % 7 == 0 { 200_000 } else { 10 }).collect();
        let out = parallel_map(&items, 4, |_, &n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u64> = vec![];
        let out = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }
}
