//! Property-testing mini-framework (proptest replacement).
//!
//! `check` runs a property over `cases` randomly generated inputs with a
//! fixed seed base (deterministic CI) and, on failure, re-reports the
//! failing seed so the case can be replayed. Generators are plain closures
//! over [`crate::util::rng::Rng`] — enough to sweep the coordinator
//! invariants (routing, batching, pipeline state) the tests target.
//!
//! [`tensors_bit_identical`] is the one shared bit-exactness oracle for
//! output tensor lists — the serving differentials (routed vs dedicated,
//! pooled vs single-worker), the optimizer parity properties, and the
//! gated benches all compare through it so "bit-for-bit" means the same
//! thing everywhere.

use crate::runtime::{Tensor, TensorData};
use crate::util::rng::Rng;

/// Bit-level equality of two output tensor lists: tensor counts and
/// shapes strict, I64 exact, F32 by bit pattern — with NaN equal to NaN
/// (a bit-exactness oracle must not reject matching NaN results), other
/// dtype pairings rejected. `Err` names the first mismatching position
/// and values so callers can prefix their own context (variant, level,
/// request id) without reimplementing the walk.
pub fn tensors_bit_identical(got: &[Tensor], want: &[Tensor]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{} tensors vs expected {}", got.len(), want.len()));
    }
    for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
        if a.shape != b.shape {
            return Err(format!("output {i}: shape {:?} vs {:?}", a.shape, b.shape));
        }
        match (&a.data, &b.data) {
            (TensorData::I64(p), TensorData::I64(q)) => {
                if let Some(j) = (0..p.len().min(q.len())).find(|&j| p[j] != q[j]) {
                    return Err(format!("output {i}[{j}]: i64 {} vs {}", p[j], q[j]));
                }
                if p.len() != q.len() {
                    return Err(format!("output {i}: i64 len {} vs {}", p.len(), q.len()));
                }
            }
            (TensorData::F32(p), TensorData::F32(q)) => {
                for (j, (u, v)) in p.iter().zip(q.iter()).enumerate() {
                    let same = u.to_bits() == v.to_bits() || (u.is_nan() && v.is_nan());
                    if !same {
                        return Err(format!("output {i}[{j}]: {u:?} vs {v:?}"));
                    }
                }
                if p.len() != q.len() {
                    return Err(format!("output {i}: f32 len {} vs {}", p.len(), q.len()));
                }
            }
            other => return Err(format!("output {i}: dtype mismatch {other:?}")),
        }
    }
    Ok(())
}

/// Run `property` over `cases` inputs drawn from `gen`. Panics with the
/// failing seed and debug-printed input on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0xA5A5_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !property(&input) {
            panic!("property '{name}' failed at seed {seed:#x} with input: {input:?}");
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC3C3_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}\ninput: {input:?}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random ASCII-ish string of length in [0, max_len].
    pub fn string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                // mix of letters, digits, separators and a few unicode chars
                const ALPHABET: &[char] = &[
                    'a', 'b', 'c', 'x', 'y', 'z', 'A', 'Z', '0', '1', '9', ' ', '-', '_', '|',
                    ',', '.', '/', 'é', 'ß', '中',
                ];
                ALPHABET[rng.below(ALPHABET.len() as u64) as usize]
            })
            .collect()
    }

    /// Random f64 in a "interesting" mixture: uniform, large, tiny,
    /// negative, zero.
    pub fn f64_mixed(rng: &mut Rng) -> f64 {
        match rng.below(6) {
            0 => 0.0,
            1 => rng.range_f64(-1.0, 1.0),
            2 => rng.range_f64(-1e9, 1e9),
            3 => rng.range_f64(0.0, 1e-9),
            4 => -rng.range_f64(0.0, 1e6),
            _ => rng.range_f64(0.0, 1e3),
        }
    }

    /// Vector of length in [min_len, max_len] from an element generator.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut el: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
        (0..len).map(|_| el(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is identity", 50,
            |rng| gen::vec_of(rng, 0, 20, |r| r.next_u64()),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                *v == w
            });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 5, |rng| rng.next_u64(), |_| false);
    }

    #[test]
    fn tensors_bit_identical_oracle() {
        let a = Tensor::f32(vec![1.0, f32::NAN], vec![2]).unwrap();
        let b = Tensor::f32(vec![1.0, f32::NAN], vec![2]).unwrap();
        // NaN == NaN: matching NaNs must not fail a bit-exactness pin
        assert!(tensors_bit_identical(&[a.clone()], &[b]).is_ok());
        let c = Tensor::f32(vec![1.0, 2.0], vec![2]).unwrap();
        assert!(tensors_bit_identical(&[a.clone()], &[c]).is_err());
        let i = Tensor::i64(vec![1, 2], vec![2]).unwrap();
        let err = tensors_bit_identical(&[a.clone()], &[i.clone()]).unwrap_err();
        assert!(err.contains("dtype"), "{err}");
        let err = tensors_bit_identical(&[], &[i.clone()]).unwrap_err();
        assert!(err.contains("tensors"), "{err}");
        let j = Tensor::i64(vec![1, 3], vec![2]).unwrap();
        let err = tensors_bit_identical(&[i.clone()], &[j]).unwrap_err();
        assert!(err.contains("i64"), "{err}");
        // -0.0 vs 0.0 differ by bit pattern: strict by design
        let z0 = Tensor::f32(vec![0.0], vec![1]).unwrap();
        let z1 = Tensor::f32(vec![-0.0], vec![1]).unwrap();
        assert!(tensors_bit_identical(&[z0], &[z1]).is_err());
    }

    #[test]
    fn string_gen_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let s = gen::string(&mut rng, 12);
            assert!(s.chars().count() <= 12);
        }
    }
}
