//! Property-testing mini-framework (proptest replacement).
//!
//! `check` runs a property over `cases` randomly generated inputs with a
//! fixed seed base (deterministic CI) and, on failure, re-reports the
//! failing seed so the case can be replayed. Generators are plain closures
//! over [`crate::util::rng::Rng`] — enough to sweep the coordinator
//! invariants (routing, batching, pipeline state) the tests target.

use crate::util::rng::Rng;

/// Run `property` over `cases` inputs drawn from `gen`. Panics with the
/// failing seed and debug-printed input on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0xA5A5_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !property(&input) {
            panic!("property '{name}' failed at seed {seed:#x} with input: {input:?}");
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = 0xC3C3_0000 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}\ninput: {input:?}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    /// Random ASCII-ish string of length in [0, max_len].
    pub fn string(rng: &mut Rng, max_len: usize) -> String {
        let len = rng.below(max_len as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                // mix of letters, digits, separators and a few unicode chars
                const ALPHABET: &[char] = &[
                    'a', 'b', 'c', 'x', 'y', 'z', 'A', 'Z', '0', '1', '9', ' ', '-', '_', '|',
                    ',', '.', '/', 'é', 'ß', '中',
                ];
                ALPHABET[rng.below(ALPHABET.len() as u64) as usize]
            })
            .collect()
    }

    /// Random f64 in a "interesting" mixture: uniform, large, tiny,
    /// negative, zero.
    pub fn f64_mixed(rng: &mut Rng) -> f64 {
        match rng.below(6) {
            0 => 0.0,
            1 => rng.range_f64(-1.0, 1.0),
            2 => rng.range_f64(-1e9, 1e9),
            3 => rng.range_f64(0.0, 1e-9),
            4 => -rng.range_f64(0.0, 1e6),
            _ => rng.range_f64(0.0, 1e3),
        }
    }

    /// Vector of length in [min_len, max_len] from an element generator.
    pub fn vec_of<T>(
        rng: &mut Rng,
        min_len: usize,
        max_len: usize,
        mut el: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
        (0..len).map(|_| el(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("reverse twice is identity", 50,
            |rng| gen::vec_of(rng, 0, 20, |r| r.next_u64()),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                *v == w
            });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics() {
        check("always fails", 5, |rng| rng.next_u64(), |_| false);
    }

    #[test]
    fn string_gen_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let s = gen::string(&mut rng, 12);
            assert!(s.chars().count() <= 12);
        }
    }
}
