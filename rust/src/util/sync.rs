//! Shared synchronization primitives.
//!
//! The offline vendor set has no tokio/parking_lot, so the handful of
//! primitives the project needs beyond `std::sync` live here. Today that
//! is a counting [`Semaphore`] built on `Mutex` + `Condvar`, used by two
//! subsystems:
//!
//! - the streaming orchestrator (`engine::stream`) bounds its in-flight
//!   micro-batch queue with blocking [`Semaphore::acquire`] calls
//!   (backpressure: the producer sleeps until a slot frees up), and
//! - the network front-end (`serving::net`) bounds in-flight HTTP
//!   requests with non-blocking [`Semaphore::try_acquire`] calls
//!   (load shedding: a request that finds no slot is answered `429`
//!   immediately instead of queueing).

use std::sync::{Condvar, Mutex};

/// A counting semaphore over `n` permits.
///
/// `acquire`/`release` may be called from different threads (the stream
/// orchestrator acquires on the producer thread and releases on the sink
/// thread), so the permit count lives behind a `Mutex` rather than being
/// tied to a guard lifetime.
pub struct Semaphore {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    /// A semaphore holding `n` permits.
    pub fn new(n: usize) -> Self {
        Semaphore { count: Mutex::new(n), cv: Condvar::new() }
    }

    /// Block until a permit is available, then take it.
    pub fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c == 0 {
            c = self.cv.wait(c).unwrap();
        }
        *c -= 1;
    }

    /// Take a permit if one is available right now; never blocks.
    ///
    /// Returns `true` if a permit was taken. The caller owns the permit
    /// and must `release` it exactly once.
    pub fn try_acquire(&self) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c == 0 {
            false
        } else {
            *c -= 1;
            true
        }
    }

    /// Return a permit and wake one waiter.
    pub fn release(&self) {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        self.cv.notify_one();
    }

    /// Number of permits currently available (racy by nature; useful for
    /// metrics and tests, not for flow control).
    pub fn available(&self) -> usize {
        *self.count.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn try_acquire_counts_down_then_refuses() {
        let s = Semaphore::new(2);
        assert_eq!(s.available(), 2);
        assert!(s.try_acquire());
        assert!(s.try_acquire());
        assert_eq!(s.available(), 0);
        assert!(!s.try_acquire());
        s.release();
        assert_eq!(s.available(), 1);
        assert!(s.try_acquire());
        assert!(!s.try_acquire());
    }

    #[test]
    fn acquire_blocks_until_cross_thread_release() {
        let s = Arc::new(Semaphore::new(0));
        let released = Arc::new(AtomicBool::new(false));

        let waiter = {
            let s = Arc::clone(&s);
            let released = Arc::clone(&released);
            std::thread::spawn(move || {
                s.acquire();
                // acquire must not return before the releasing thread ran
                assert!(released.load(Ordering::SeqCst));
            })
        };

        std::thread::sleep(Duration::from_millis(50));
        released.store(true, Ordering::SeqCst);
        s.release();
        waiter.join().unwrap();
        assert_eq!(s.available(), 0);
    }

    #[test]
    fn zero_permit_semaphore_refuses_try_acquire() {
        let s = Semaphore::new(0);
        assert!(!s.try_acquire());
        s.release();
        assert!(s.try_acquire());
    }
}
