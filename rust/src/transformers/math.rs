//! Mathematical transformers (Kamae's math family).

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::math::{self, BinOp, UnaryOp};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::{spec_out_name, spec_output_cast, Io};

/// Shared implementation for all single-input unary math transformers:
/// each public transformer type is a configuration of [`UnaryOp`].
#[derive(Debug, Clone)]
pub struct UnaryMathTransformer {
    pub(crate) io: Io,
    pub(crate) op: UnaryOp,
    type_name: &'static str,
}

impl UnaryMathTransformer {
    fn new(io: Io, op: UnaryOp, type_name: &'static str) -> Self {
        UnaryMathTransformer { io, op, type_name }
    }

    fn attrs(&self) -> Json {
        let mut a = Json::object();
        match &self.op {
            UnaryOp::Log { base } => {
                if let Some(b) = base {
                    a.set("base", *b);
                }
            }
            UnaryOp::Clip { min, max } => {
                if let Some(m) = min {
                    a.set("min", *m);
                }
                if let Some(m) = max {
                    a.set("max", *m);
                }
            }
            UnaryOp::PowScalar { p } => {
                a.set("p", *p);
            }
            UnaryOp::AddScalar { c }
            | UnaryOp::SubScalar { c }
            | UnaryOp::MulScalar { c }
            | UnaryOp::DivScalar { c } => {
                a.set("c", *c);
            }
            UnaryOp::ScaleShift { scale, shift } => {
                a.set("scale", *scale);
                a.set("shift", *shift);
            }
            _ => {}
        }
        a
    }

    pub(crate) fn op_from_json(op_name: &str, j: &Json) -> Result<UnaryOp> {
        Ok(match op_name {
            "log" => UnaryOp::Log { base: j.opt_f64("base") },
            "log1p" => UnaryOp::Log1p,
            "exp" => UnaryOp::Exp,
            "sqrt" => UnaryOp::Sqrt,
            "abs" => UnaryOp::Abs,
            "neg" => UnaryOp::Neg,
            "reciprocal" => UnaryOp::Reciprocal,
            "round" => UnaryOp::Round,
            "floor" => UnaryOp::Floor,
            "ceil" => UnaryOp::Ceil,
            "sin" => UnaryOp::Sin,
            "cos" => UnaryOp::Cos,
            "tanh" => UnaryOp::Tanh,
            "sigmoid" => UnaryOp::Sigmoid,
            "clip" => UnaryOp::Clip { min: j.opt_f64("min"), max: j.opt_f64("max") },
            "pow_scalar" => UnaryOp::PowScalar { p: j.req_f64("p")? },
            "add_scalar" => UnaryOp::AddScalar { c: j.req_f64("c")? },
            "sub_scalar" => UnaryOp::SubScalar { c: j.req_f64("c")? },
            "mul_scalar" => UnaryOp::MulScalar { c: j.req_f64("c")? },
            "div_scalar" => UnaryOp::DivScalar { c: j.req_f64("c")? },
            "scale_shift" => UnaryOp::ScaleShift {
                scale: j.req_f64("scale")?,
                shift: j.req_f64("shift")?,
            },
            other => {
                return Err(KamaeError::Serde(format!("unknown unary op: {other}")))
            }
        })
    }
}

impl Transformer for UnaryMathTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        self.type_name
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let out = math::unary(&input, &self.op)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(
            self.op.spec_name(),
            &[self.io.input()],
            self.attrs(),
            &out,
            SpecDType::F32,
            width,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, width)
    }

    fn save(&self) -> Json {
        let mut j = self.attrs();
        j.set("op", self.op.spec_name());
        self.io.write_json(&mut j);
        j
    }
}

/// Construct the concrete transformer types the public API exposes.
macro_rules! unary_transformer {
    ($(#[$doc:meta])* $name:ident, $type_tag:literal, ($($arg:ident : $ty:ty),*), $op:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone)]
        pub struct $name(pub(crate) UnaryMathTransformer);

        impl $name {
            #[allow(clippy::new_without_default)]
            pub fn new(input: &str, output: &str $(, $arg: $ty)*) -> $name {
                $name(UnaryMathTransformer::new(
                    Io::single(input, output),
                    $op,
                    $type_tag,
                ))
            }

            /// Set the Kamae `layerName`.
            pub fn layer_name(mut self, name: &str) -> Self {
                self.0.io.layer_name = name.to_string();
                self
            }

            /// Cast inputs before the op (`inputDtype`).
            pub fn input_dtype(mut self, dt: crate::dataframe::DType) -> Self {
                self.0.io.input_dtype = Some(dt);
                self
            }

            /// Cast the output after the op (`outputDtype`).
            pub fn output_dtype(mut self, dt: crate::dataframe::DType) -> Self {
                self.0.io.output_dtype = Some(dt);
                self
            }
        }

        impl Transformer for $name {
            fn layer_name(&self) -> &str { &self.0.io.layer_name }
            fn type_name(&self) -> &'static str { Transformer::type_name(&self.0) }
            fn transform(&self, df: &mut DataFrame) -> Result<()> { self.0.transform(df) }
            fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> { self.0.spec_nodes(b) }
            fn save(&self) -> Json { self.0.save() }
        }
    };
}

unary_transformer!(
    /// `log(x + alpha)` in the configured base (Kamae `LogTransformer`).
    /// With `alpha = 1` and base *e* this is the paper's log1p transform
    /// for "values spanning many orders of magnitude".
    LogTransformer, "LogTransformer", (), UnaryOp::Log { base: None });

impl LogTransformer {
    /// Use a specific logarithm base.
    pub fn base(mut self, base: f64) -> Self {
        self.0.op = UnaryOp::Log { base: Some(base) };
        self
    }

    /// Switch to log1p (log(1+x), base e).
    pub fn log1p(mut self) -> Self {
        self.0.op = UnaryOp::Log1p;
        self
    }
}

unary_transformer!(
    /// e^x (Kamae `ExpTransformer`).
    ExpTransformer, "ExpTransformer", (), UnaryOp::Exp);
unary_transformer!(
    /// √x.
    SqrtTransformer, "SqrtTransformer", (), UnaryOp::Sqrt);
unary_transformer!(
    /// |x|.
    AbsTransformer, "AbsTransformer", (), UnaryOp::Abs);
unary_transformer!(
    /// −x.
    NegTransformer, "NegTransformer", (), UnaryOp::Neg);
unary_transformer!(
    /// 1/x.
    ReciprocalTransformer, "ReciprocalTransformer", (), UnaryOp::Reciprocal);
unary_transformer!(
    /// Round half-to-even.
    RoundTransformer, "RoundTransformer", (), UnaryOp::Round);
unary_transformer!(
    /// ⌊x⌋.
    FloorTransformer, "FloorTransformer", (), UnaryOp::Floor);
unary_transformer!(
    /// ⌈x⌉.
    CeilTransformer, "CeilTransformer", (), UnaryOp::Ceil);
unary_transformer!(
    /// sin(x).
    SinTransformer, "SinTransformer", (), UnaryOp::Sin);
unary_transformer!(
    /// cos(x).
    CosTransformer, "CosTransformer", (), UnaryOp::Cos);
unary_transformer!(
    /// tanh(x).
    TanhTransformer, "TanhTransformer", (), UnaryOp::Tanh);
unary_transformer!(
    /// σ(x) = 1/(1+e^−x).
    SigmoidTransformer, "SigmoidTransformer", (), UnaryOp::Sigmoid);
unary_transformer!(
    /// Clamp into [min, max] (Kamae `ClipTransformer`).
    ClipTransformer, "ClipTransformer", (min: Option<f64>, max: Option<f64>),
    UnaryOp::Clip { min, max });
unary_transformer!(
    /// x^p (Kamae `PowerTransformer`).
    PowerTransformer, "PowerTransformer", (p: f64), UnaryOp::PowScalar { p });
unary_transformer!(
    /// x + c.
    AddConstantTransformer, "AddConstantTransformer", (c: f64), UnaryOp::AddScalar { c });
unary_transformer!(
    /// x − c.
    SubtractConstantTransformer, "SubtractConstantTransformer", (c: f64), UnaryOp::SubScalar { c });
unary_transformer!(
    /// x · c.
    MultiplyConstantTransformer, "MultiplyConstantTransformer", (c: f64), UnaryOp::MulScalar { c });
unary_transformer!(
    /// x / c.
    DivideConstantTransformer, "DivideConstantTransformer", (c: f64), UnaryOp::DivScalar { c });
unary_transformer!(
    /// x·scale + shift (the exported form of standard scaling).
    ScaleShiftTransformer, "ScaleShiftTransformer", (scale: f64, shift: f64),
    UnaryOp::ScaleShift { scale, shift });

/// Elementwise arithmetic between two columns (Kamae's binary math
/// transformers: `SumTransformer`, `SubtractTransformer`, ... — here one
/// type parameterised by [`BinOp`]).
#[derive(Debug, Clone)]
pub struct ArithmeticTransformer {
    io: Io,
    op: BinOp,
}

impl ArithmeticTransformer {
    crate::io_builder_methods!();

    pub fn new(left: &str, right: &str, output: &str, op: BinOp) -> Self {
        ArithmeticTransformer { io: Io::multi(&[left, right], output), op }
    }
}

impl Transformer for ArithmeticTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ArithmeticTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        let b = self.io.get(df, 1)?;
        let out = math::binary(&a, &b, self.op)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let wa = b.width(&self.io.input_cols[0])?;
        let wb = b.width(&self.io.input_cols[1])?;
        let width = wa.or(wb); // broadcast: list side wins
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(
            self.op.spec_name(),
            &[&self.io.input_cols[0], &self.io.input_cols[1]],
            Json::object(),
            &out,
            SpecDType::F32,
            width,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("op", self.op.spec_name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn arithmetic_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let io = Io::from_json(j)?;
    let op = BinOp::from_name(j.req_str("op")?)?;
    Ok(Box::new(ArithmeticTransformer { io, op }))
}

pub(crate) fn unary_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let io = Io::from_json(j)?;
    let op = UnaryMathTransformer::op_from_json(j.req_str("op")?, j)?;
    // the concrete wrapper type is irrelevant after load; reuse the shared
    // implementation with a stable tag so re-save round-trips.
    Ok(Box::new(UnaryMathTransformer::new(io, op, "UnaryMath")))
}

/// Bucketize by explicit splits (Spark `Bucketizer`).
#[derive(Debug, Clone)]
pub struct BucketizeTransformer {
    io: Io,
    splits: Vec<f64>,
}

impl BucketizeTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, splits: Vec<f64>) -> Self {
        BucketizeTransformer { io: Io::single(input, output), splits }
    }
}

impl Transformer for BucketizeTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "BucketizeTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let out = math::bucketize(&input, &self.splits)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let mut attrs = Json::object();
        attrs.set("splits", Json::Array(self.splits.iter().map(|&s| Json::Float(s)).collect()));
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(op_names::BUCKETIZE, &[self.io.input()], attrs, &out, SpecDType::I64, width)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("splits", Json::Array(self.splits.iter().map(|&s| Json::Float(s)).collect()));
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn bucketize_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let io = Io::from_json(j)?;
    let splits = j
        .req_array("splits")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| KamaeError::Serde("split".into())))
        .collect::<Result<_>>()?;
    Ok(Box::new(BucketizeTransformer { io, splits }))
}

/// Row-wise min/max/sum/mean over N columns (Kamae's multi-column math).
#[derive(Debug, Clone)]
pub struct ColumnsAggTransformer {
    io: Io,
    agg: ColumnsAgg,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnsAgg {
    Sum,
    Mean,
    Min,
    Max,
}

impl ColumnsAgg {
    fn name(&self) -> &'static str {
        match self {
            ColumnsAgg::Sum => "sum",
            ColumnsAgg::Mean => "mean",
            ColumnsAgg::Min => "min",
            ColumnsAgg::Max => "max",
        }
    }

    fn parse(s: &str) -> Result<ColumnsAgg> {
        Ok(match s {
            "sum" => ColumnsAgg::Sum,
            "mean" => ColumnsAgg::Mean,
            "min" => ColumnsAgg::Min,
            "max" => ColumnsAgg::Max,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown columns agg: {other}")))
            }
        })
    }
}

impl ColumnsAggTransformer {
    crate::io_builder_methods!();

    pub fn new(inputs: &[&str], output: &str, agg: ColumnsAgg) -> Self {
        ColumnsAggTransformer { io: Io::multi(inputs, output), agg }
    }
}

impl Transformer for ColumnsAggTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ColumnsAggTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let mut acc = crate::ops::cast::to_f64_vec(&self.io.get(df, 0)?)?;
        let mut cols = vec![self.io.get(df, 0)?];
        for i in 1..self.io.input_cols.len() {
            let c = self.io.get(df, i)?;
            let v = crate::ops::cast::to_f64_vec(&c)?;
            if v.len() != acc.len() {
                return Err(KamaeError::LengthMismatch {
                    left: v.len(),
                    right: acc.len(),
                    context: "columns agg".into(),
                });
            }
            for (a, &x) in acc.iter_mut().zip(v.iter()) {
                *a = match self.agg {
                    ColumnsAgg::Sum | ColumnsAgg::Mean => *a + x,
                    ColumnsAgg::Min => a.min(x),
                    ColumnsAgg::Max => a.max(x),
                };
            }
            cols.push(c);
        }
        if self.agg == ColumnsAgg::Mean {
            let n = self.io.input_cols.len() as f64;
            for a in acc.iter_mut() {
                *a /= n;
            }
        }
        let refs: Vec<&crate::dataframe::Column> = cols.iter().collect();
        let mut out = crate::dataframe::Column::F64(acc, None);
        out.set_nulls(crate::ops::merge_nulls(&refs))?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let inputs: Vec<&str> = self.io.input_cols.iter().map(String::as_str).collect();
        let mut attrs = Json::object();
        attrs.set("agg", self.agg.name());
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(op_names::COLUMNS_AGG, &inputs, attrs, &out, SpecDType::F32, None)?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("agg", self.agg.name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn columns_agg_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let io = Io::from_json(j)?;
    let agg = ColumnsAgg::parse(j.req_str("agg")?)?;
    Ok(Box::new(ColumnsAggTransformer { io, agg }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("x".into(), Column::from_f64(vec![1.0, 10.0, 100.0])),
            ("y".into(), Column::from_f64(vec![2.0, 3.0, 4.0])),
        ])
        .unwrap()
    }

    #[test]
    fn log_transformer() {
        let mut d = df();
        LogTransformer::new("x", "x_log").base(10.0).transform(&mut d).unwrap();
        let out = d.column("x_log").unwrap().as_f64().unwrap();
        assert!((out[2] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn output_dtype_cast() {
        let mut d = df();
        SqrtTransformer::new("x", "s")
            .output_dtype(crate::dataframe::DType::I64)
            .transform(&mut d)
            .unwrap();
        assert_eq!(d.column("s").unwrap().as_i64().unwrap(), &[1, 3, 10]);
    }

    #[test]
    fn arithmetic() {
        let mut d = df();
        ArithmeticTransformer::new("x", "y", "q", BinOp::Div).transform(&mut d).unwrap();
        assert_eq!(d.column("q").unwrap().as_f64().unwrap(), &[0.5, 10.0 / 3.0, 25.0]);
    }

    #[test]
    fn columns_agg_all_modes() {
        let mut d = df();
        for (agg, expect0) in [
            (ColumnsAgg::Sum, 3.0),
            (ColumnsAgg::Mean, 1.5),
            (ColumnsAgg::Min, 1.0),
            (ColumnsAgg::Max, 2.0),
        ] {
            let t = ColumnsAggTransformer::new(&["x", "y"], "agg", agg);
            t.transform(&mut d).unwrap();
            assert_eq!(d.column("agg").unwrap().as_f64().unwrap()[0], expect0, "{agg:?}");
        }
    }

    #[test]
    fn bucketize_transformer() {
        let mut d = df();
        BucketizeTransformer::new("x", "b", vec![5.0, 50.0]).transform(&mut d).unwrap();
        assert_eq!(d.column("b").unwrap().as_i64().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn save_load_roundtrip() {
        let t = LogTransformer::new("x", "x_log").base(2.0).layer_name("my_log");
        let j = crate::pipeline::with_type(t.save(), t.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut d = df();
        loaded.transform(&mut d).unwrap();
        assert!((d.column("x_log").unwrap().as_f64().unwrap()[1] - 10.0f64.log2()).abs() < 1e-12);
        assert_eq!(loaded.layer_name(), "my_log");
    }
}
