//! Shared transformer parameter plumbing (Kamae's common params:
//! `inputCol(s)`, `outputCol`, `layerName`, `inputDtype`, `outputDtype`).

use crate::dataframe::{Column, DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::ops::cast;
use crate::util::json::Json;

/// Common I/O configuration carried by every transformer.
#[derive(Debug, Clone)]
pub struct Io {
    pub input_cols: Vec<String>,
    pub output_col: String,
    pub layer_name: String,
    /// Optional cast applied to inputs before the op (Listing 1's
    /// `inputDtype="string"`).
    pub input_dtype: Option<DType>,
    /// Optional cast applied to the output after the op.
    pub output_dtype: Option<DType>,
}

impl Io {
    pub fn single(input: &str, output: &str) -> Io {
        Io {
            input_cols: vec![input.to_string()],
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            input_dtype: None,
            output_dtype: None,
        }
    }

    pub fn multi(inputs: &[&str], output: &str) -> Io {
        Io {
            input_cols: inputs.iter().map(|s| s.to_string()).collect(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            input_dtype: None,
            output_dtype: None,
        }
    }

    pub fn input(&self) -> &str {
        &self.input_cols[0]
    }

    /// Fetch input `i`, applying the `inputDtype` cast if configured.
    pub fn get(&self, df: &DataFrame, i: usize) -> Result<Column> {
        let name = self.input_cols.get(i).ok_or_else(|| {
            KamaeError::InvalidConfig(format!(
                "{}: missing input column index {i}",
                self.layer_name
            ))
        })?;
        let col = df.column(name)?;
        match &self.input_dtype {
            Some(dt) => cast::cast(col, dt),
            None => Ok(col.clone()),
        }
    }

    /// Store the op result, applying the `outputDtype` cast if configured.
    pub fn finish(&self, df: &mut DataFrame, col: Column) -> Result<()> {
        let col = match &self.output_dtype {
            Some(dt) => cast::cast(&col, dt)?,
            None => col,
        };
        df.set_column(self.output_col.clone(), col)
    }

    // ---- JSON ----------------------------------------------------------

    pub fn write_json(&self, j: &mut Json) {
        if self.input_cols.len() == 1 {
            j.set("inputCol", self.input_cols[0].clone());
        } else {
            j.set(
                "inputCols",
                Json::Array(self.input_cols.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        if let Some(dt) = &self.input_dtype {
            j.set("inputDtype", dt.name());
        }
        if let Some(dt) = &self.output_dtype {
            j.set("outputDtype", dt.name());
        }
    }

    pub fn from_json(j: &Json) -> Result<Io> {
        let input_cols: Vec<String> = if let Some(one) = j.opt_str("inputCol") {
            vec![one.to_string()]
        } else {
            j.req_array("inputCols")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| KamaeError::Serde("inputCols entry".into()))
                })
                .collect::<Result<_>>()?
        };
        let output_col = j.req_str("outputCol")?.to_string();
        Ok(Io {
            layer_name: j
                .opt_str("layerName")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{output_col}_layer")),
            input_cols,
            output_col,
            input_dtype: j.opt_str("inputDtype").map(DType::parse).transpose()?,
            output_dtype: j.opt_str("outputDtype").map(DType::parse).transpose()?,
        })
    }
}

/// Builder-style setters shared by all transformer config structs.
#[macro_export]
macro_rules! io_builder_methods {
    () => {
        /// Set the Kamae `layerName`.
        pub fn layer_name(mut self, name: &str) -> Self {
            self.io.layer_name = name.to_string();
            self
        }

        /// Cast inputs to this dtype before the op (`inputDtype`).
        pub fn input_dtype(mut self, dt: crate::dataframe::DType) -> Self {
            self.io.input_dtype = Some(dt);
            self
        }

        /// Cast the output to this dtype after the op (`outputDtype`).
        pub fn output_dtype(mut self, dt: crate::dataframe::DType) -> Self {
            self.io.output_dtype = Some(dt);
            self
        }
    };
}

/// Append the spec-side output cast node if `outputDtype` forces a dtype
/// class change (float↔int). Returns the final graph column name.
pub fn spec_output_cast(
    b: &mut crate::export::SpecBuilder,
    io: &Io,
    produced: &str,
    produced_dtype: crate::export::SpecDType,
    width: Option<usize>,
) -> Result<()> {
    use crate::export::SpecDType;
    let Some(target) = &io.output_dtype else {
        return Ok(());
    };
    let target_spec = SpecDType::for_engine(target);
    if target_spec == produced_dtype || matches!(target, DType::Str | DType::List(_)) {
        return Ok(());
    }
    // rename: produced op wrote to a temp name `<out>__pre`; here we cast
    // into the real output name.
    let op = match target_spec {
        SpecDType::I64 => crate::optim::names::TO_I64,
        SpecDType::F32 => crate::optim::names::TO_F32,
    };
    b.graph_node(op, &[produced], Json::object(), &io.output_col, target_spec, width)?;
    Ok(())
}

/// Decide the graph-node output name: if an output cast is needed the op
/// writes to `<out>__pre` and [`spec_output_cast`] writes the final name.
pub fn spec_out_name(io: &Io, produced_dtype: crate::export::SpecDType) -> String {
    use crate::export::SpecDType;
    match &io.output_dtype {
        Some(t) => {
            let t_spec = SpecDType::for_engine(t);
            if t_spec != produced_dtype && !matches!(t, DType::Str | DType::List(_)) {
                format!("{}__pre", io.output_col)
            } else {
                io.output_col.clone()
            }
        }
        None => io.output_col.clone(),
    }
}
