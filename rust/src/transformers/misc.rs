//! Geographic and dtype-cast transformers.

use crate::dataframe::{DataFrame, DType};
use crate::error::Result;
use crate::export::{SpecBuilder, SpecDType};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::{spec_out_name, spec_output_cast, Io};

/// Haversine great-circle distance (km) between two coordinate pairs.
#[derive(Debug, Clone)]
pub struct HaversineTransformer {
    io: Io,
}

impl HaversineTransformer {
    crate::io_builder_methods!();

    /// inputs = [lat1, lon1, lat2, lon2]
    pub fn new(lat1: &str, lon1: &str, lat2: &str, lon2: &str, output: &str) -> Self {
        HaversineTransformer { io: Io::multi(&[lat1, lon1, lat2, lon2], output) }
    }
}

impl Transformer for HaversineTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "HaversineTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let cols: Vec<crate::dataframe::Column> =
            (0..4).map(|i| self.io.get(df, i)).collect::<Result<_>>()?;
        let out = crate::ops::geo::haversine(&cols[0], &cols[1], &cols[2], &cols[3])?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let inputs: Vec<&str> = self.io.input_cols.iter().map(String::as_str).collect();
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(op_names::HAVERSINE, &inputs, Json::object(), &out, SpecDType::F32, None)?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn haversine_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(HaversineTransformer { io: Io::from_json(j)? }))
}

/// Pure dtype cast as a pipeline stage.
#[derive(Debug, Clone)]
pub struct CastTransformer {
    io: Io,
    to: DType,
}

impl CastTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, to: DType) -> Self {
        CastTransformer { io: Io::single(input, output), to }
    }
}

impl Transformer for CastTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "CastTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, crate::ops::cast::cast(&input, &self.to)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let in_dtype = b.engine_dtype(self.io.input())?.clone();
        match &self.to {
            // cast to string: ingress op (canonical string form)
            DType::Str => b.ingress_node(
                op_names::TO_STRING,
                &[self.io.input()],
                Json::object(),
                &self.io.output_col,
                DType::Str,
                width,
            ),
            // numeric casts: graph-side convert between f32/i64 classes
            to => {
                let target = SpecDType::for_engine(to);
                let op = match target {
                    SpecDType::I64 => op_names::TO_I64,
                    SpecDType::F32 => op_names::TO_F32,
                };
                // string inputs cast to number stay ingress (parsing)
                let is_string_in = matches!(in_dtype, DType::Str)
                    || matches!(&in_dtype, DType::List(i) if matches!(**i, DType::Str));
                if is_string_in {
                    b.ingress_node(
                        op_names::PARSE_NUMBER,
                        &[self.io.input()],
                        Json::object(),
                        &self.io.output_col,
                        to.clone(),
                        width,
                    )
                } else {
                    b.graph_node(op, &[self.io.input()], Json::object(), &self.io.output_col, target, width)?;
                    Ok(())
                }
            }
        }
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("to", self.to.name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn cast_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(CastTransformer {
        io: Io::from_json(j)?,
        to: DType::parse(j.req_str("to")?)?,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    #[test]
    fn haversine_distance() {
        let mut d = DataFrame::new(vec![
            ("la1".into(), Column::from_f64(vec![51.5074])),
            ("lo1".into(), Column::from_f64(vec![-0.1278])),
            ("la2".into(), Column::from_f64(vec![48.8566])),
            ("lo2".into(), Column::from_f64(vec![2.3522])),
        ])
        .unwrap();
        HaversineTransformer::new("la1", "lo1", "la2", "lo2", "dist")
            .transform(&mut d)
            .unwrap();
        assert!((d.column("dist").unwrap().as_f64().unwrap()[0] - 344.0).abs() < 5.0);
    }

    #[test]
    fn cast_stage() {
        let mut d = DataFrame::new(vec![(
            "x".into(),
            Column::from_str(vec!["1.5", "2.5"]),
        )])
        .unwrap();
        CastTransformer::new("x", "xf", DType::F64).transform(&mut d).unwrap();
        assert_eq!(d.column("xf").unwrap().as_f64().unwrap(), &[1.5, 2.5]);
    }
}
