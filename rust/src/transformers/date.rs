//! Date/time transformers — the paper's LTR pipeline "disassembles date
//! features into parts (month, weekday) so the model can accommodate
//! seasonality" and "subtracts particular dates to generate durations".
//!
//! Parsing is ingress-side (strings); part extraction and arithmetic are
//! graph-side integer math on epoch days/seconds (see
//! [`crate::ops::date`]).

use crate::dataframe::{DataFrame, DType};
use crate::error::Result;
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::date::{self, DatePart};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::{spec_out_name, spec_output_cast, Io};

/// Parse `YYYY-MM-DD` strings → days since epoch (I64).
#[derive(Debug, Clone)]
pub struct DateParseTransformer {
    io: Io,
}

impl DateParseTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        DateParseTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for DateParseTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "DateParseTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, date::date_to_days(&input)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        b.ingress_node(op_names::DATE_TO_DAYS, &[self.io.input()], Json::object(), &self.io.output_col, DType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn date_parse_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(DateParseTransformer { io: Io::from_json(j)? }))
}

/// Parse `YYYY-MM-DD HH:MM:SS` strings → seconds since epoch (I64).
#[derive(Debug, Clone)]
pub struct TimestampParseTransformer {
    io: Io,
}

impl TimestampParseTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        TimestampParseTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for TimestampParseTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "TimestampParseTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, date::timestamp_to_seconds(&input)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        b.ingress_node(op_names::TIMESTAMP_TO_SECONDS, &[self.io.input()], Json::object(), &self.io.output_col, DType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn timestamp_parse_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(TimestampParseTransformer { io: Io::from_json(j)? }))
}

/// Extract a calendar part (year/month/day/weekday/day-of-year) from an
/// epoch-days column — graph-side integer math.
#[derive(Debug, Clone)]
pub struct DatePartTransformer {
    io: Io,
    part: DatePart,
}

impl DatePartTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, part: DatePart) -> Self {
        DatePartTransformer { io: Io::single(input, output), part }
    }
}

impl Transformer for DatePartTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "DatePartTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, date::extract_part(&input, self.part)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("part", self.part.spec_name());
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(op_names::DATE_PART, &[self.io.input()], attrs, &out, SpecDType::I64, None)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("part", self.part.spec_name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn date_part_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(DatePartTransformer {
        io: Io::from_json(j)?,
        part: DatePart::from_name(j.req_str("part")?)?,
    }))
}

/// Difference in days between two epoch-days columns (durations).
#[derive(Debug, Clone)]
pub struct DateDiffTransformer {
    io: Io,
}

impl DateDiffTransformer {
    crate::io_builder_methods!();

    /// `output = end - start` in days.
    pub fn new(end: &str, start: &str, output: &str) -> Self {
        DateDiffTransformer { io: Io::multi(&[end, start], output) }
    }
}

impl Transformer for DateDiffTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "DateDiffTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let end = self.io.get(df, 0)?;
        let start = self.io.get(df, 1)?;
        let (e, s) = (end.as_i64()?, start.as_i64()?);
        let data: Vec<i64> = e.iter().zip(s.iter()).map(|(&a, &b)| a - b).collect();
        let mut out = crate::dataframe::Column::I64(data, None);
        out.set_nulls(crate::ops::merge_nulls(&[&end, &start]))?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(
            op_names::SUB_I64,
            &[&self.io.input_cols[0], &self.io.input_cols[1]],
            Json::object(),
            &out,
            SpecDType::I64,
            None,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn date_diff_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(DateDiffTransformer { io: Io::from_json(j)? }))
}

/// Add a constant number of days to an epoch-days column.
#[derive(Debug, Clone)]
pub struct DateAddTransformer {
    io: Io,
    days: i64,
}

impl DateAddTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, days: i64) -> Self {
        DateAddTransformer { io: Io::single(input, output), days }
    }
}

impl Transformer for DateAddTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "DateAddTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let v = input.as_i64()?;
        let data: Vec<i64> = v.iter().map(|&x| x + self.days).collect();
        self.io.finish(df, crate::dataframe::Column::I64(data, input.nulls().cloned()))
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("c", self.days);
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(op_names::ADD_SCALAR_I64, &[self.io.input()], attrs, &out, SpecDType::I64, None)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("days", self.days);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn date_add_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(DateAddTransformer {
        io: Io::from_json(j)?,
        days: j.req_i64("days")?,
    }))
}

/// Seconds-since-epoch → days-since-epoch (floor division; graph-side).
#[derive(Debug, Clone)]
pub struct SecondsToDaysTransformer {
    io: Io,
}

impl SecondsToDaysTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        SecondsToDaysTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for SecondsToDaysTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "SecondsToDaysTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let v = input.as_i64()?;
        let data: Vec<i64> = v.iter().map(|&x| x.div_euclid(86_400)).collect();
        self.io.finish(df, crate::dataframe::Column::I64(data, input.nulls().cloned()))
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let out = spec_out_name(&self.io, SpecDType::I64);
        let mut attrs = Json::object();
        attrs.set("c", 86_400i64);
        b.graph_node(op_names::FLOORDIV_SCALAR_I64, &[self.io.input()], attrs, &out, SpecDType::I64, None)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn seconds_to_days_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(SecondsToDaysTransformer { io: Io::from_json(j)? }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            (
                "checkin".into(),
                Column::from_str(vec!["2024-06-15", "2024-12-31"]),
            ),
            (
                "checkout".into(),
                Column::from_str(vec!["2024-06-18", "2025-01-02"]),
            ),
            (
                "ts".into(),
                Column::from_str(vec!["2024-06-15 12:30:00", "2024-12-31 23:59:59"]),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn duration_pipeline() {
        // the paper's "particular dates are subtracted to generate durations"
        let mut d = df();
        DateParseTransformer::new("checkin", "in_days").transform(&mut d).unwrap();
        DateParseTransformer::new("checkout", "out_days").transform(&mut d).unwrap();
        DateDiffTransformer::new("out_days", "in_days", "stay_len").transform(&mut d).unwrap();
        assert_eq!(d.column("stay_len").unwrap().as_i64().unwrap(), &[3, 2]);
    }

    #[test]
    fn seasonality_parts() {
        let mut d = df();
        DateParseTransformer::new("checkin", "days").transform(&mut d).unwrap();
        DatePartTransformer::new("days", "month", DatePart::Month).transform(&mut d).unwrap();
        DatePartTransformer::new("days", "wd", DatePart::Weekday).transform(&mut d).unwrap();
        assert_eq!(d.column("month").unwrap().as_i64().unwrap(), &[6, 12]);
        assert_eq!(d.column("wd").unwrap().as_i64().unwrap(), &[6, 2]); // Sat, Tue
    }

    #[test]
    fn timestamp_flow() {
        let mut d = df();
        TimestampParseTransformer::new("ts", "secs").transform(&mut d).unwrap();
        SecondsToDaysTransformer::new("secs", "days").transform(&mut d).unwrap();
        DatePartTransformer::new("days", "y", DatePart::Year).transform(&mut d).unwrap();
        assert_eq!(d.column("y").unwrap().as_i64().unwrap(), &[2024, 2024]);
    }

    #[test]
    fn date_add() {
        let mut d = df();
        DateParseTransformer::new("checkin", "days").transform(&mut d).unwrap();
        DateAddTransformer::new("days", "later", 30).transform(&mut d).unwrap();
        DatePartTransformer::new("later", "m", DatePart::Month).transform(&mut d).unwrap();
        assert_eq!(d.column("m").unwrap().as_i64().unwrap(), &[7, 1]);
    }
}
