//! Array / sequence transformers (Kamae's nested-sequence-native family).

use crate::dataframe::{DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::array::{self, ListAgg};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::{spec_out_name, spec_output_cast, Io};

/// Assemble N numeric scalar columns into one fixed-width vector column
/// (the paper's "assembled into a single array which is subsequently
/// standard scaled").
#[derive(Debug, Clone)]
pub struct VectorAssembleTransformer {
    io: Io,
}

impl VectorAssembleTransformer {
    crate::io_builder_methods!();

    pub fn new(inputs: &[&str], output: &str) -> Self {
        VectorAssembleTransformer { io: Io::multi(inputs, output) }
    }
}

impl Transformer for VectorAssembleTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "VectorAssembleTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let cols: Vec<crate::dataframe::Column> = (0..self.io.input_cols.len())
            .map(|i| self.io.get(df, i))
            .collect::<Result<_>>()?;
        let refs: Vec<&crate::dataframe::Column> = cols.iter().collect();
        self.io.finish(df, array::assemble(&refs)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let inputs: Vec<&str> = self.io.input_cols.iter().map(String::as_str).collect();
        let w = inputs.len();
        b.graph_node(
            op_names::ASSEMBLE,
            &inputs,
            Json::object(),
            &self.io.output_col,
            SpecDType::F32,
            Some(w),
        )?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn assemble_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(VectorAssembleTransformer { io: Io::from_json(j)? }))
}

/// Disassemble a fixed-width vector column into scalar columns named
/// `<outputCol>_0..N` (or explicit `outputCols`).
#[derive(Debug, Clone)]
pub struct VectorDisassembleTransformer {
    io: Io,
    output_cols: Vec<String>,
}

impl VectorDisassembleTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, outputs: &[&str]) -> Self {
        VectorDisassembleTransformer {
            io: Io::single(input, outputs.first().copied().unwrap_or("disassembled")),
            output_cols: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl Transformer for VectorDisassembleTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "VectorDisassembleTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let parts = array::disassemble(&input)?;
        if parts.len() != self.output_cols.len() {
            return Err(KamaeError::InvalidConfig(format!(
                "{}: vector has width {}, {} output cols configured",
                self.io.layer_name,
                parts.len(),
                self.output_cols.len()
            )));
        }
        for (name, col) in self.output_cols.iter().zip(parts) {
            df.set_column(name.clone(), col)?;
        }
        Ok(())
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        for (i, name) in self.output_cols.iter().enumerate() {
            let mut attrs = Json::object();
            attrs.set("index", i);
            b.graph_node(op_names::VECTOR_AT, &[self.io.input()], attrs, name, SpecDType::F32, None)?;
        }
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set(
            "outputCols",
            Json::Array(self.output_cols.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn disassemble_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let output_cols: Vec<String> = j
        .req_array("outputCols")?
        .iter()
        .map(|s| {
            s.as_str()
                .map(str::to_string)
                .ok_or_else(|| KamaeError::Serde("outputCols entry".into()))
        })
        .collect::<Result<_>>()?;
    Ok(Box::new(VectorDisassembleTransformer { io: Io::from_json(j)?, output_cols }))
}

/// Reduce each row's list to a scalar (sum/mean/min/max/len).
#[derive(Debug, Clone)]
pub struct ListAggregateTransformer {
    io: Io,
    agg: ListAgg,
}

impl ListAggregateTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, agg: ListAgg) -> Self {
        ListAggregateTransformer { io: Io::single(input, output), agg }
    }
}

impl Transformer for ListAggregateTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ListAggregateTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, array::aggregate(&input, self.agg)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let dtype = if self.agg == ListAgg::Len { SpecDType::I64 } else { SpecDType::F32 };
        let out = spec_out_name(&self.io, dtype);
        b.graph_node(self.agg.spec_name(), &[self.io.input()], Json::object(), &out, dtype, None)?;
        spec_output_cast(b, &self.io, &out, dtype, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set(
            "agg",
            match self.agg {
                ListAgg::Sum => "sum",
                ListAgg::Mean => "mean",
                ListAgg::Min => "min",
                ListAgg::Max => "max",
                ListAgg::Len => "len",
            },
        );
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn list_agg_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(ListAggregateTransformer {
        io: Io::from_json(j)?,
        agg: ListAgg::from_name(j.req_str("agg")?)?,
    }))
}

/// Element at a fixed position of each row's list.
#[derive(Debug, Clone)]
pub struct ElementAtTransformer {
    io: Io,
    index: i64,
}

impl ElementAtTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, index: i64) -> Self {
        ElementAtTransformer { io: Io::single(input, output), index }
    }
}

impl Transformer for ElementAtTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ElementAtTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, array::element_at(&input, self.index)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let in_dtype = b.engine_dtype(self.io.input())?.clone();
        let is_string = matches!(&in_dtype, DType::List(i) if matches!(**i, DType::Str));
        let dtype = match &in_dtype {
            DType::List(inner) => SpecDType::for_engine(inner),
            _ => SpecDType::F32,
        };
        let mut attrs = Json::object();
        attrs.set("index", self.index);
        if is_string {
            // element extraction of a string list is still ingress work
            b.ingress_node(op_names::ELEMENT_AT, &[self.io.input()], attrs, &self.io.output_col, DType::Str, None)
        } else {
            let out = spec_out_name(&self.io, dtype);
            b.graph_node(op_names::ELEMENT_AT, &[self.io.input()], attrs, &out, dtype, None)?;
            spec_output_cast(b, &self.io, &out, dtype, None)
        }
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("index", self.index);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn element_at_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(ElementAtTransformer {
        io: Io::from_json(j)?,
        index: j.req_i64("index")?,
    }))
}

/// Slice `[start, start+len)` of each row's list.
#[derive(Debug, Clone)]
pub struct ListSliceTransformer {
    io: Io,
    start: usize,
    len: usize,
}

impl ListSliceTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, start: usize, len: usize) -> Self {
        ListSliceTransformer { io: Io::single(input, output), start, len }
    }
}

impl Transformer for ListSliceTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ListSliceTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, array::slice_list(&input, self.start, self.len)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let in_dtype = b.engine_dtype(self.io.input())?.clone();
        let in_width = b.width(self.io.input())?;
        let out_width = match in_width {
            Some(w) => self.len.min(w.saturating_sub(self.start)),
            None => self.len,
        };
        let mut attrs = Json::object();
        attrs.set("start", self.start).set("len", self.len);
        let is_string = matches!(&in_dtype, DType::List(i) if matches!(**i, DType::Str));
        if is_string {
            b.ingress_node(
                op_names::SLICE_LIST,
                &[self.io.input()],
                attrs,
                &self.io.output_col,
                in_dtype,
                Some(out_width),
            )
        } else {
            let dtype = match &in_dtype {
                DType::List(inner) => SpecDType::for_engine(inner),
                _ => SpecDType::F32,
            };
            b.graph_node(op_names::SLICE_LIST, &[self.io.input()], attrs, &self.io.output_col, dtype, Some(out_width))?;
            Ok(())
        }
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("start", self.start).set("len", self.len);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn list_slice_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(ListSliceTransformer {
        io: Io::from_json(j)?,
        start: j.req_i64("start")? as usize,
        len: j.req_i64("len")? as usize,
    }))
}

/// Row-wise cosine similarity between two fixed-width vector columns
/// (Kamae's `CosineSimilarityTransformer` — e.g. user-embedding vs
/// item-embedding similarity as a ranking feature).
#[derive(Debug, Clone)]
pub struct CosineSimilarityTransformer {
    io: Io,
}

impl CosineSimilarityTransformer {
    crate::io_builder_methods!();

    pub fn new(left: &str, right: &str, output: &str) -> Self {
        CosineSimilarityTransformer { io: Io::multi(&[left, right], output) }
    }
}

impl Transformer for CosineSimilarityTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "CosineSimilarityTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        let b = self.io.get(df, 1)?;
        self.io.finish(df, array::cosine_similarity(&a, &b)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let wa = b.width(&self.io.input_cols[0])?;
        let wb = b.width(&self.io.input_cols[1])?;
        if wa.is_none() || wa != wb {
            return Err(KamaeError::InvalidConfig(format!(
                "{}: cosine similarity needs two fixed-width vectors of equal width",
                self.io.layer_name
            )));
        }
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(
            op_names::COSINE_SIMILARITY,
            &[&self.io.input_cols[0], &self.io.input_cols[1]],
            Json::object(),
            &out,
            SpecDType::F32,
            None,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn cosine_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(CosineSimilarityTransformer { io: Io::from_json(j)? }))
}

/// Pad/truncate a numeric or string list to a fixed length (the generic
/// version of Listing 1's padding; required before a list crosses into
/// the compiled graph).
#[derive(Debug, Clone)]
pub struct ListPadTransformer {
    io: Io,
    len: usize,
    default: String,
}

impl ListPadTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, len: usize, default: &str) -> Self {
        ListPadTransformer { io: Io::single(input, output), len, default: default.to_string() }
    }
}

impl Transformer for ListPadTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ListPadTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, crate::ops::string_ops::pad_list(&input, self.len, &self.default)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let in_dtype = b.engine_dtype(self.io.input())?.clone();
        let mut attrs = Json::object();
        attrs.set("len", self.len).set("default", self.default.clone());
        // padding is ingress work for strings; for numerics it is a graph
        // op only if the input is already fixed-width — otherwise it is
        // the op that *makes* it fixed-width, i.e. ingress.
        b.ingress_node(op_names::PAD_LIST, &[self.io.input()], attrs, &self.io.output_col, in_dtype, Some(self.len))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("len", self.len).set("default", self.default.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn list_pad_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(ListPadTransformer {
        io: Io::from_json(j)?,
        len: j.req_i64("len")? as usize,
        default: j.req_str("default")?.to_string(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("a".into(), Column::from_f64(vec![1.0, 2.0])),
            ("b".into(), Column::from_f64(vec![3.0, 4.0])),
            ("l".into(), Column::from_f64_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0]])),
        ])
        .unwrap()
    }

    #[test]
    fn assemble_scale_disassemble_pattern() {
        let mut d = df();
        VectorAssembleTransformer::new(&["a", "b"], "vec").transform(&mut d).unwrap();
        VectorDisassembleTransformer::new("vec", &["a2", "b2"]).transform(&mut d).unwrap();
        assert_eq!(d.column("a2").unwrap().as_f64().unwrap(), &[1.0, 2.0]);
        assert_eq!(d.column("b2").unwrap().as_f64().unwrap(), &[3.0, 4.0]);
    }

    #[test]
    fn disassemble_width_mismatch_errors() {
        let mut d = df();
        VectorAssembleTransformer::new(&["a", "b"], "vec").transform(&mut d).unwrap();
        let t = VectorDisassembleTransformer::new("vec", &["only_one"]);
        assert!(t.transform(&mut d).is_err());
    }

    #[test]
    fn cosine_similarity_stage() {
        let mut d = DataFrame::new(vec![
            ("u".into(), Column::from_f64_rows(vec![vec![1.0, 0.0], vec![3.0, 4.0]])),
            ("v".into(), Column::from_f64_rows(vec![vec![0.0, 2.0], vec![3.0, 4.0]])),
        ])
        .unwrap();
        CosineSimilarityTransformer::new("u", "v", "sim").transform(&mut d).unwrap();
        let s = d.column("sim").unwrap().as_f64().unwrap();
        assert!(s[0].abs() < 1e-12);
        assert!((s[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn list_ops() {
        let mut d = df();
        ListAggregateTransformer::new("l", "sum", ListAgg::Sum).transform(&mut d).unwrap();
        assert_eq!(d.column("sum").unwrap().as_f64().unwrap(), &[6.0, 4.0]);
        ElementAtTransformer::new("l", "first", 0).transform(&mut d).unwrap();
        assert_eq!(d.column("first").unwrap().as_f64().unwrap(), &[1.0, 4.0]);
        ListSliceTransformer::new("l", "sl", 1, 2).transform(&mut d).unwrap();
        assert_eq!(d.column("sl").unwrap().as_list_f64().unwrap().row(0), &[2.0, 3.0]);
        ListPadTransformer::new("l", "pad", 2, "0").transform(&mut d).unwrap();
        let p = d.column("pad").unwrap().as_list_f64().unwrap();
        assert_eq!(p.row(1), &[4.0, 0.0]);
        assert!(p.is_fixed_width(2));
    }
}
