//! Stateless indexing transformers: hash indexing and bloom encoding
//! (Listing 1's `HashIndexTransformer`; bloom per Serrà & Karatzoglou).
//!
//! Both run entirely graph-side on 64-bit token hashes produced by the
//! ingress `hash64` op — the Pallas `hash_bucket`/`bloom_probes` kernels
//! mirror [`crate::ops::hash`] bit-exactly.

use crate::dataframe::DataFrame;
use crate::error::Result;
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::hash;
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::Io;

/// Map a (string) feature into `[0, numBins)` by hashing — for
/// overwhelming-cardinality categoricals (Listing 1: `UserID`, 10k bins).
#[derive(Debug, Clone)]
pub struct HashIndexTransformer {
    io: Io,
    num_bins: i64,
}

impl HashIndexTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, num_bins: i64) -> Self {
        HashIndexTransformer { io: Io::single(input, output), num_bins }
    }
}

impl Transformer for HashIndexTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "HashIndexTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        // inputDtype handling: hashing always goes through the canonical
        // string form, so ints hash identically on both paths.
        let hashed = hash::hash64_column(&input)?;
        let out = hash::hash_bucket_column(&hashed, self.num_bins)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        // force the hash64 ingress boundary even for numeric inputs
        let href = hash_ref(b, self.io.input(), width)?;
        let mut attrs = Json::object();
        attrs.set("num_bins", self.num_bins);
        b.graph_node(op_names::HASH_BUCKET, &[&href], attrs, &self.io.output_col, SpecDType::I64, width)?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("numBins", self.num_bins);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn hash_index_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(HashIndexTransformer {
        io: Io::from_json(j)?,
        num_bins: j.req_i64("numBins")?,
    }))
}

/// Bloom encoding: k hash probes per token, probe j offset into
/// `[j·numBins, (j+1)·numBins)` — memory-efficient high-cardinality
/// encoding (experiment C4 sweeps k and numBins).
#[derive(Debug, Clone)]
pub struct BloomEncodeTransformer {
    io: Io,
    num_hashes: usize,
    num_bins: i64,
}

impl BloomEncodeTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, num_hashes: usize, num_bins: i64) -> Self {
        BloomEncodeTransformer { io: Io::single(input, output), num_hashes, num_bins }
    }
}

impl Transformer for BloomEncodeTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "BloomEncodeTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let out = hash::bloom_encode_column(&input, self.num_hashes, self.num_bins)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let in_width = b.width(self.io.input())?;
        if in_width.is_some() {
            return Err(crate::error::KamaeError::Unsupported(
                "bloom encoding of list features (encode elements before padding instead)".into(),
            ));
        }
        let href = hash_ref(b, self.io.input(), None)?;
        let mut attrs = Json::object();
        attrs.set("num_hashes", self.num_hashes).set("num_bins", self.num_bins);
        b.graph_node(
            op_names::BLOOM_ENCODE,
            &[&href],
            attrs,
            &self.io.output_col,
            SpecDType::I64,
            Some(self.num_hashes),
        )?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("numHashes", self.num_hashes).set("numBins", self.num_bins);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn bloom_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(BloomEncodeTransformer {
        io: Io::from_json(j)?,
        num_hashes: j.req_i64("numHashes")? as usize,
        num_bins: j.req_i64("numBins")?,
    }))
}

/// Resolve a column to its hashed graph reference, inserting the `hash64`
/// ingress node even when the engine dtype is numeric (indexers hash the
/// canonical string form — matching `inputDtype="string"` semantics).
pub(crate) fn hash_ref(
    b: &mut SpecBuilder,
    col: &str,
    width: Option<usize>,
) -> Result<String> {
    use crate::dataframe::DType;
    let dt = b.engine_dtype(col)?.clone();
    let is_string = matches!(dt, DType::Str)
        || matches!(&dt, DType::List(i) if matches!(**i, DType::Str));
    if is_string {
        // builder's auto-hash path
        b.graph_ref(col)
    } else {
        let hashed = format!("{col}__hash");
        if b.engine_dtype(&hashed).is_err() {
            let out_dtype = if matches!(dt, DType::List(_)) {
                DType::List(Box::new(DType::I64))
            } else {
                DType::I64
            };
            b.ingress_node(op_names::HASH64, &[col], Json::object(), &hashed, out_dtype, width)?;
        }
        b.graph_ref(&hashed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("user".into(), Column::from_i64(vec![42, 99, 42])),
            ("city".into(), Column::from_str(vec!["NYC", "LON", "PAR"])),
        ])
        .unwrap()
    }

    #[test]
    fn hash_index_stable_and_bounded() {
        let mut d = df();
        HashIndexTransformer::new("user", "u_idx", 10_000)
            .input_dtype(crate::dataframe::DType::Str)
            .transform(&mut d)
            .unwrap();
        let idx = d.column("u_idx").unwrap().as_i64().unwrap();
        assert_eq!(idx[0], idx[2]); // same id, same bin
        assert!(idx.iter().all(|&i| (0..10_000).contains(&i)));
        // must equal hashing the canonical string form
        assert_eq!(idx[0], hash::bucket(hash::fnv1a64("42"), 0, 10_000));
    }

    #[test]
    fn bloom_encode_shape() {
        let mut d = df();
        BloomEncodeTransformer::new("city", "c_bloom", 3, 500).transform(&mut d).unwrap();
        let l = d.column("c_bloom").unwrap().as_list_i64().unwrap();
        assert!(l.is_fixed_width(3));
        for row in l.rows() {
            for (k, &v) in row.iter().enumerate() {
                assert!((k as i64 * 500..(k as i64 + 1) * 500).contains(&v));
            }
        }
    }

    #[test]
    fn save_load() {
        let t = HashIndexTransformer::new("user", "u", 64).layer_name("uh");
        let j = crate::pipeline::with_type(t.save(), t.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut a = df();
        let mut b = df();
        t.transform(&mut a).unwrap();
        loaded.transform(&mut b).unwrap();
        assert_eq!(a, b);
    }
}
