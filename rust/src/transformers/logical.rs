//! Logical / conditional transformers.

use crate::dataframe::{Column, DataFrame, DType};
use crate::error::Result;
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::logical::{self, BoolOp, CmpOp};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::{spec_out_name, spec_output_cast, Io};

/// Compare two numeric columns → bool (graph-side; bool travels as I64).
#[derive(Debug, Clone)]
pub struct CompareTransformer {
    io: Io,
    op: CmpOp,
}

impl CompareTransformer {
    crate::io_builder_methods!();

    pub fn new(left: &str, right: &str, output: &str, op: CmpOp) -> Self {
        CompareTransformer { io: Io::multi(&[left, right], output), op }
    }
}

impl Transformer for CompareTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "CompareTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        let b = self.io.get(df, 1)?;
        self.io.finish(df, logical::compare(&a, &b, self.op)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let out = spec_out_name(&self.io, SpecDType::I64);
        let mut attrs = Json::object();
        attrs.set("op", self.op.spec_name());
        b.graph_node(
            op_names::COMPARE,
            &[&self.io.input_cols[0], &self.io.input_cols[1]],
            attrs,
            &out,
            SpecDType::I64,
            None,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("op", self.op.spec_name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn compare_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(CompareTransformer {
        io: Io::from_json(j)?,
        op: CmpOp::from_name(j.req_str("op")?)?,
    }))
}

/// Compare a column against a numeric constant → bool.
#[derive(Debug, Clone)]
pub struct CompareConstantTransformer {
    io: Io,
    op: CmpOp,
    value: f64,
}

impl CompareConstantTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, op: CmpOp, value: f64) -> Self {
        CompareConstantTransformer { io: Io::single(input, output), op, value }
    }
}

impl Transformer for CompareConstantTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "CompareConstantTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        self.io.finish(df, logical::compare_scalar(&a, self.value, self.op)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let out = spec_out_name(&self.io, SpecDType::I64);
        let mut attrs = Json::object();
        attrs.set("op", self.op.spec_name()).set("value", self.value);
        b.graph_node(op_names::COMPARE_SCALAR, &[self.io.input()], attrs, &out, SpecDType::I64, width)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("op", self.op.spec_name()).set("value", self.value);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn compare_constant_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(CompareConstantTransformer {
        io: Io::from_json(j)?,
        op: CmpOp::from_name(j.req_str("op")?)?,
        value: j.req_f64("value")?,
    }))
}

/// String equality against a constant → bool. Engine compares strings;
/// the compiled graph compares 64-bit token hashes (same answer modulo a
/// 2⁻⁶⁴ collision — DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct StringEqualsTransformer {
    io: Io,
    value: String,
}

impl StringEqualsTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, value: &str) -> Self {
        StringEqualsTransformer { io: Io::single(input, output), value: value.to_string() }
    }
}

impl Transformer for StringEqualsTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringEqualsTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        self.io.finish(df, logical::equals_str(&a, &self.value)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let out = spec_out_name(&self.io, SpecDType::I64);
        let mut attrs = Json::object();
        attrs.set("value_hash", crate::ops::hash::fnv1a64(&self.value));
        b.graph_node(op_names::EQ_HASH, &[self.io.input()], attrs, &out, SpecDType::I64, width)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("value", self.value.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn string_equals_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringEqualsTransformer {
        io: Io::from_json(j)?,
        value: j.req_str("value")?.to_string(),
    }))
}

/// and/or/xor of two bool columns.
#[derive(Debug, Clone)]
pub struct BooleanTransformer {
    io: Io,
    op: BoolOp,
}

impl BooleanTransformer {
    crate::io_builder_methods!();

    pub fn new(left: &str, right: &str, output: &str, op: BoolOp) -> Self {
        BooleanTransformer { io: Io::multi(&[left, right], output), op }
    }
}

impl Transformer for BooleanTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "BooleanTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        let b = self.io.get(df, 1)?;
        self.io.finish(df, logical::bool_binary(&a, &b, self.op)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let out = spec_out_name(&self.io, SpecDType::I64);
        let mut attrs = Json::object();
        attrs.set("op", self.op.spec_name());
        b.graph_node(
            op_names::BOOL_OP,
            &[&self.io.input_cols[0], &self.io.input_cols[1]],
            attrs,
            &out,
            SpecDType::I64,
            None,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("op", self.op.spec_name());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn boolean_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(BooleanTransformer {
        io: Io::from_json(j)?,
        op: BoolOp::from_name(j.req_str("op")?)?,
    }))
}

/// Boolean negation.
#[derive(Debug, Clone)]
pub struct NotTransformer {
    io: Io,
}

impl NotTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        NotTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for NotTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "NotTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        self.io.finish(df, logical::bool_not(&a)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(op_names::NOT, &[self.io.input()], Json::object(), &out, SpecDType::I64, width)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn not_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(NotTransformer { io: Io::from_json(j)? }))
}

/// `if cond then left else right`, elementwise (Kamae's conditional
/// transformer). Branch columns must share a numeric dtype.
#[derive(Debug, Clone)]
pub struct IfThenElseTransformer {
    io: Io,
}

impl IfThenElseTransformer {
    crate::io_builder_methods!();

    /// inputs = [cond, then_col, else_col]
    pub fn new(cond: &str, then_col: &str, else_col: &str, output: &str) -> Self {
        IfThenElseTransformer { io: Io::multi(&[cond, then_col, else_col], output) }
    }
}

impl Transformer for IfThenElseTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "IfThenElseTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let c = self.io.get(df, 0)?;
        let a = self.io.get(df, 1)?;
        let b = self.io.get(df, 2)?;
        // normalise both branches to f64 so mixed int/float configs work
        let a = crate::ops::cast::cast(&a, &DType::F64)?;
        let b = crate::ops::cast::cast(&b, &DType::F64)?;
        self.io.finish(df, logical::select(&c, &a, &b)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let out = spec_out_name(&self.io, SpecDType::F32);
        b.graph_node(
            op_names::SELECT,
            &[
                &self.io.input_cols[0],
                &self.io.input_cols[1],
                &self.io.input_cols[2],
            ],
            Json::object(),
            &out,
            SpecDType::F32,
            None,
        )?;
        spec_output_cast(b, &self.io, &out, SpecDType::F32, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn if_then_else_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(IfThenElseTransformer { io: Io::from_json(j)? }))
}

/// Null indicator for float columns (null or NaN → true). Serving-side
/// the graph tests NaN — the ingress encodes nulls as NaN for floats.
#[derive(Debug, Clone)]
pub struct IsNullTransformer {
    io: Io,
}

impl IsNullTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        IsNullTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for IsNullTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "IsNullTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let a = self.io.get(df, 0)?;
        let vals = crate::ops::cast::to_f64_vec(&a)?;
        let data: Vec<bool> = vals
            .iter()
            .enumerate()
            .map(|(i, &x)| a.is_null(i) || x.is_nan())
            .collect();
        self.io.finish(df, Column::from_bool(data))
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let out = spec_out_name(&self.io, SpecDType::I64);
        b.graph_node(op_names::IS_NAN, &[self.io.input()], Json::object(), &out, SpecDType::I64, width)?;
        spec_output_cast(b, &self.io, &out, SpecDType::I64, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn is_null_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(IsNullTransformer { io: Io::from_json(j)? }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("x".into(), Column::from_f64(vec![1.0, 5.0, 3.0])),
            ("y".into(), Column::from_f64(vec![2.0, 2.0, 3.0])),
            ("city".into(), Column::from_str(vec!["NYC", "LON", "NYC"])),
        ])
        .unwrap()
    }

    #[test]
    fn compare_and_select() {
        let mut d = df();
        CompareTransformer::new("x", "y", "gt", CmpOp::Gt).transform(&mut d).unwrap();
        assert_eq!(d.column("gt").unwrap().as_bool().unwrap(), &[false, true, false]);
        IfThenElseTransformer::new("gt", "x", "y", "m").transform(&mut d).unwrap();
        assert_eq!(d.column("m").unwrap().as_f64().unwrap(), &[2.0, 5.0, 3.0]);
    }

    #[test]
    fn compare_constant_and_bool_ops() {
        let mut d = df();
        CompareConstantTransformer::new("x", "big", CmpOp::Ge, 3.0).transform(&mut d).unwrap();
        CompareConstantTransformer::new("y", "small", CmpOp::Lt, 3.0).transform(&mut d).unwrap();
        BooleanTransformer::new("big", "small", "both", BoolOp::And).transform(&mut d).unwrap();
        assert_eq!(d.column("both").unwrap().as_bool().unwrap(), &[false, true, false]);
        NotTransformer::new("both", "neither").transform(&mut d).unwrap();
        assert_eq!(d.column("neither").unwrap().as_bool().unwrap(), &[true, false, true]);
    }

    #[test]
    fn string_equals() {
        let mut d = df();
        StringEqualsTransformer::new("city", "is_nyc", "NYC").transform(&mut d).unwrap();
        assert_eq!(d.column("is_nyc").unwrap().as_bool().unwrap(), &[true, false, true]);
    }

    #[test]
    fn is_null_covers_nan_and_mask() {
        let mut d = DataFrame::new(vec![(
            "v".into(),
            Column::from_f64_opt(vec![Some(1.0), None, Some(f64::NAN)]),
        )])
        .unwrap();
        IsNullTransformer::new("v", "missing").transform(&mut d).unwrap();
        assert_eq!(d.column("missing").unwrap().as_bool().unwrap(), &[false, true, true]);
    }
}
