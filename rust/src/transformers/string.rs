//! String transformers (ingress-side ops).

use crate::dataframe::{DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::export::SpecBuilder;
use crate::ops::regex::Regex;
use crate::ops::string_ops::{self, CaseMode, MatchMode};
use crate::pipeline::Transformer;
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::common::Io;

/// Upper/lower/title casing (Kamae `StringCaseTransformer`).
#[derive(Debug, Clone)]
pub struct StringCaseTransformer {
    io: Io,
    mode: CaseMode,
}

impl StringCaseTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, mode: CaseMode) -> Self {
        StringCaseTransformer { io: Io::single(input, output), mode }
    }
}

fn case_name(m: CaseMode) -> &'static str {
    match m {
        CaseMode::Upper => "upper",
        CaseMode::Lower => "lower",
        CaseMode::Title => "title",
    }
}

fn case_parse(s: &str) -> Result<CaseMode> {
    Ok(match s {
        "upper" => CaseMode::Upper,
        "lower" => CaseMode::Lower,
        "title" => CaseMode::Title,
        other => return Err(KamaeError::InvalidConfig(format!("unknown case mode: {other}"))),
    })
}

impl Transformer for StringCaseTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringCaseTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let out = string_ops::change_case(&input, self.mode)?;
        self.io.finish(df, out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let dt = b.engine_dtype(self.io.input())?.clone();
        let mut attrs = Json::object();
        attrs.set("mode", case_name(self.mode));
        b.ingress_node(op_names::CASE, &[self.io.input()], attrs, &self.io.output_col, dt, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("mode", case_name(self.mode));
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn case_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringCaseTransformer {
        io: Io::from_json(j)?,
        mode: case_parse(j.req_str("mode")?)?,
    }))
}

/// Whitespace trim.
#[derive(Debug, Clone)]
pub struct TrimTransformer {
    io: Io,
}

impl TrimTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        TrimTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for TrimTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "TrimTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, string_ops::trim(&input)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let dt = b.engine_dtype(self.io.input())?.clone();
        b.ingress_node(op_names::TRIM, &[self.io.input()], Json::object(), &self.io.output_col, dt, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn trim_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(TrimTransformer { io: Io::from_json(j)? }))
}

/// Substring by char offsets.
#[derive(Debug, Clone)]
pub struct SubstringTransformer {
    io: Io,
    start: usize,
    len: usize,
}

impl SubstringTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, start: usize, len: usize) -> Self {
        SubstringTransformer { io: Io::single(input, output), start, len }
    }
}

impl Transformer for SubstringTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "SubstringTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, string_ops::substring(&input, self.start, self.len)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("start", self.start).set("len", self.len);
        b.ingress_node(op_names::SUBSTRING, &[self.io.input()], attrs, &self.io.output_col, DType::Str, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("start", self.start).set("len", self.len);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn substring_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(SubstringTransformer {
        io: Io::from_json(j)?,
        start: j.req_i64("start")? as usize,
        len: j.req_i64("len")? as usize,
    }))
}

/// Literal find/replace.
#[derive(Debug, Clone)]
pub struct StringReplaceTransformer {
    io: Io,
    from: String,
    to: String,
}

impl StringReplaceTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, from: &str, to: &str) -> Self {
        StringReplaceTransformer {
            io: Io::single(input, output),
            from: from.to_string(),
            to: to.to_string(),
        }
    }
}

impl Transformer for StringReplaceTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringReplaceTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, string_ops::replace_literal(&input, &self.from, &self.to)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let dt = b.engine_dtype(self.io.input())?.clone();
        let mut attrs = Json::object();
        attrs.set("from", self.from.clone()).set("to", self.to.clone());
        b.ingress_node(op_names::REPLACE, &[self.io.input()], attrs, &self.io.output_col, dt, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("from", self.from.clone()).set("to", self.to.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn replace_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringReplaceTransformer {
        io: Io::from_json(j)?,
        from: j.req_str("from")?.to_string(),
        to: j.req_str("to")?.to_string(),
    }))
}

/// Regex find/replace (engine regex — see [`crate::ops::regex`]).
#[derive(Debug, Clone)]
pub struct RegexReplaceTransformer {
    io: Io,
    pattern: String,
    rep: String,
    compiled: Regex,
}

impl RegexReplaceTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, pattern: &str, rep: &str) -> Result<Self> {
        Ok(RegexReplaceTransformer {
            io: Io::single(input, output),
            pattern: pattern.to_string(),
            rep: rep.to_string(),
            compiled: Regex::new(pattern)?,
        })
    }
}

impl Transformer for RegexReplaceTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "RegexReplaceTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, crate::ops::regex::regex_replace(&input, &self.compiled, &self.rep)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(self.io.input())?;
        let dt = b.engine_dtype(self.io.input())?.clone();
        let mut attrs = Json::object();
        attrs.set("pattern", self.pattern.clone()).set("rep", self.rep.clone());
        b.ingress_node(op_names::REGEX_REPLACE, &[self.io.input()], attrs, &self.io.output_col, dt, width)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("pattern", self.pattern.clone()).set("rep", self.rep.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn regex_replace_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let mut t = RegexReplaceTransformer::new("", "", j.req_str("pattern")?, j.req_str("rep")?)?;
    t.io = Io::from_json(j)?;
    Ok(Box::new(t))
}

/// Regex capture-group extraction ("" on no match).
#[derive(Debug, Clone)]
pub struct RegexExtractTransformer {
    io: Io,
    pattern: String,
    group: usize,
    compiled: Regex,
}

impl RegexExtractTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, pattern: &str, group: usize) -> Result<Self> {
        Ok(RegexExtractTransformer {
            io: Io::single(input, output),
            pattern: pattern.to_string(),
            group,
            compiled: Regex::new(pattern)?,
        })
    }
}

impl Transformer for RegexExtractTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "RegexExtractTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, crate::ops::regex::regex_extract(&input, &self.compiled, self.group)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("pattern", self.pattern.clone()).set("group", self.group);
        b.ingress_node(op_names::REGEX_EXTRACT, &[self.io.input()], attrs, &self.io.output_col, DType::Str, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("pattern", self.pattern.clone()).set("group", self.group);
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn regex_extract_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let mut t = RegexExtractTransformer::new("", "", j.req_str("pattern")?, j.req_i64("group")? as usize)?;
    t.io = Io::from_json(j)?;
    Ok(Box::new(t))
}

/// Concatenate N columns with a separator (numerics via canonical string
/// form).
#[derive(Debug, Clone)]
pub struct StringConcatTransformer {
    io: Io,
    separator: String,
}

impl StringConcatTransformer {
    crate::io_builder_methods!();

    pub fn new(inputs: &[&str], output: &str, separator: &str) -> Self {
        StringConcatTransformer { io: Io::multi(inputs, output), separator: separator.to_string() }
    }
}

impl Transformer for StringConcatTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringConcatTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let cols: Vec<crate::dataframe::Column> = (0..self.io.input_cols.len())
            .map(|i| self.io.get(df, i))
            .collect::<Result<_>>()?;
        let refs: Vec<&crate::dataframe::Column> = cols.iter().collect();
        self.io.finish(df, string_ops::concat_cols(&refs, &self.separator)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let inputs: Vec<&str> = self.io.input_cols.iter().map(String::as_str).collect();
        let mut attrs = Json::object();
        attrs.set("separator", self.separator.clone());
        b.ingress_node(op_names::CONCAT, &inputs, attrs, &self.io.output_col, DType::Str, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("separator", self.separator.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn concat_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringConcatTransformer {
        io: Io::from_json(j)?,
        separator: j.req_str("separator")?.to_string(),
    }))
}

/// Split on a separator into a **fixed-length** padded list — Listing 1's
/// `StringToStringListTransformer` (`separator`, `listLength`,
/// `defaultValue`).
#[derive(Debug, Clone)]
pub struct StringToStringListTransformer {
    io: Io,
    separator: String,
    list_length: usize,
    default_value: String,
}

impl StringToStringListTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, separator: &str, list_length: usize, default_value: &str) -> Self {
        StringToStringListTransformer {
            io: Io::single(input, output),
            separator: separator.to_string(),
            list_length,
            default_value: default_value.to_string(),
        }
    }
}

impl Transformer for StringToStringListTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringToStringListTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let split = string_ops::split(&input, &self.separator)?;
        let padded = string_ops::pad_list(&split, self.list_length, &self.default_value)?;
        self.io.finish(df, padded)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs
            .set("separator", self.separator.clone())
            .set("list_length", self.list_length)
            .set("default", self.default_value.clone());
        b.ingress_node(
            op_names::SPLIT_PAD,
            &[self.io.input()],
            attrs,
            &self.io.output_col,
            DType::List(Box::new(DType::Str)),
            Some(self.list_length),
        )
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("separator", self.separator.clone())
            .set("listLength", self.list_length)
            .set("defaultValue", self.default_value.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn split_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringToStringListTransformer {
        io: Io::from_json(j)?,
        separator: j.req_str("separator")?.to_string(),
        list_length: j.req_i64("listLength")? as usize,
        default_value: j.req_str("defaultValue")?.to_string(),
    }))
}

/// Join a string list back into one string.
#[derive(Debug, Clone)]
pub struct StringListToStringTransformer {
    io: Io,
    separator: String,
}

impl StringListToStringTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, separator: &str) -> Self {
        StringListToStringTransformer {
            io: Io::single(input, output),
            separator: separator.to_string(),
        }
    }
}

impl Transformer for StringListToStringTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringListToStringTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        let l = input.as_list_str()?;
        let data: Vec<String> = l.rows().map(|r| r.join(&self.separator)).collect();
        self.io.finish(df, crate::dataframe::Column::from_str(data))
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("separator", self.separator.clone());
        b.ingress_node(op_names::JOIN, &[self.io.input()], attrs, &self.io.output_col, DType::Str, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("separator", self.separator.clone());
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn join_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringListToStringTransformer {
        io: Io::from_json(j)?,
        separator: j.req_str("separator")?.to_string(),
    }))
}

/// Contains / starts-with / ends-with → bool (graph sees it as I64 0/1
/// computed at ingress, because the predicate needs the string).
#[derive(Debug, Clone)]
pub struct StringContainsTransformer {
    io: Io,
    needle: String,
    mode: MatchMode,
}

impl StringContainsTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str, needle: &str, mode: MatchMode) -> Self {
        StringContainsTransformer {
            io: Io::single(input, output),
            needle: needle.to_string(),
            mode,
        }
    }
}

fn match_name(m: MatchMode) -> &'static str {
    match m {
        MatchMode::Contains => "contains",
        MatchMode::StartsWith => "starts_with",
        MatchMode::EndsWith => "ends_with",
    }
}

fn match_parse(s: &str) -> Result<MatchMode> {
    Ok(match s {
        "contains" => MatchMode::Contains,
        "starts_with" => MatchMode::StartsWith,
        "ends_with" => MatchMode::EndsWith,
        other => return Err(KamaeError::InvalidConfig(format!("unknown match mode: {other}"))),
    })
}

impl Transformer for StringContainsTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringContainsTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, string_ops::string_match(&input, &self.needle, self.mode)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut attrs = Json::object();
        attrs.set("needle", self.needle.clone()).set("mode", match_name(self.mode));
        b.ingress_node(op_names::STRING_MATCH, &[self.io.input()], attrs, &self.io.output_col, DType::Bool, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("needle", self.needle.clone()).set("mode", match_name(self.mode));
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn contains_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringContainsTransformer {
        io: Io::from_json(j)?,
        needle: j.req_str("needle")?.to_string(),
        mode: match_parse(j.req_str("mode")?)?,
    }))
}

/// String length in chars.
#[derive(Debug, Clone)]
pub struct StringLengthTransformer {
    io: Io,
}

impl StringLengthTransformer {
    crate::io_builder_methods!();

    pub fn new(input: &str, output: &str) -> Self {
        StringLengthTransformer { io: Io::single(input, output) }
    }
}

impl Transformer for StringLengthTransformer {
    fn layer_name(&self) -> &str {
        &self.io.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringLengthTransformer"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let input = self.io.get(df, 0)?;
        self.io.finish(df, string_ops::str_len(&input)?)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        b.ingress_node(op_names::STR_LEN, &[self.io.input()], Json::object(), &self.io.output_col, DType::I64, None)
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        self.io.write_json(&mut j);
        j
    }
}

pub(crate) fn str_len_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(StringLengthTransformer { io: Io::from_json(j)? }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("s".into(), Column::from_str(vec!["  Action|Comedy  ", "Drama"])),
            ("n".into(), Column::from_i64(vec![7, 8])),
        ])
        .unwrap()
    }

    #[test]
    fn chained_string_pipeline() {
        let mut d = df();
        TrimTransformer::new("s", "t").transform(&mut d).unwrap();
        StringCaseTransformer::new("t", "u", CaseMode::Lower).transform(&mut d).unwrap();
        StringToStringListTransformer::new("u", "l", "|", 3, "PAD")
            .transform(&mut d)
            .unwrap();
        let l = d.column("l").unwrap().as_list_str().unwrap();
        assert_eq!(l.row(0), &["action".to_string(), "comedy".to_string(), "PAD".to_string()]);
        assert_eq!(l.row(1), &["drama".to_string(), "PAD".to_string(), "PAD".to_string()]);
    }

    #[test]
    fn concat_and_length() {
        let mut d = df();
        StringConcatTransformer::new(&["s", "n"], "c", "_").transform(&mut d).unwrap();
        assert_eq!(d.column("c").unwrap().as_str().unwrap()[1], "Drama_8");
        StringLengthTransformer::new("c", "len").transform(&mut d).unwrap();
        assert_eq!(d.column("len").unwrap().as_i64().unwrap()[1], 7);
    }

    #[test]
    fn regex_transformers() {
        let mut d = df();
        let t = RegexReplaceTransformer::new("s", "r", r"\s+", "").unwrap();
        t.transform(&mut d).unwrap();
        assert_eq!(d.column("r").unwrap().as_str().unwrap()[0], "Action|Comedy");
        let e = RegexExtractTransformer::new("s", "x", r"(\w+)\|", 1).unwrap();
        e.transform(&mut d).unwrap();
        assert_eq!(d.column("x").unwrap().as_str().unwrap()[0], "Action");
        assert_eq!(d.column("x").unwrap().as_str().unwrap()[1], "");
    }

    #[test]
    fn join_roundtrip() {
        let mut d = df();
        StringToStringListTransformer::new("s", "l", "|", 2, "P").transform(&mut d).unwrap();
        StringListToStringTransformer::new("l", "j", "+").transform(&mut d).unwrap();
        assert_eq!(d.column("j").unwrap().as_str().unwrap()[1], "Drama+P");
    }

    #[test]
    fn save_load_all() {
        let d = df();
        let transformers: Vec<Box<dyn Transformer>> = vec![
            Box::new(StringCaseTransformer::new("s", "o1", CaseMode::Title)),
            Box::new(TrimTransformer::new("s", "o2")),
            Box::new(SubstringTransformer::new("s", "o3", 2, 4)),
            Box::new(StringReplaceTransformer::new("s", "o4", "|", ";")),
            Box::new(RegexReplaceTransformer::new("s", "o5", r"\d+", "#").unwrap()),
            Box::new(RegexExtractTransformer::new("s", "o6", r"(\w+)", 1).unwrap()),
            Box::new(StringConcatTransformer::new(&["s", "n"], "o7", "-")),
            Box::new(StringToStringListTransformer::new("s", "o8", "|", 2, "P")),
            Box::new(StringContainsTransformer::new("s", "o9", "Drama", MatchMode::Contains)),
            Box::new(StringLengthTransformer::new("s", "o10")),
        ];
        for t in transformers {
            let j = crate::pipeline::with_type(t.save(), t.type_name());
            let loaded = crate::transformers::load(&j).unwrap();
            let mut a = d.clone();
            let mut b = d.clone();
            t.transform(&mut a).unwrap();
            loaded.transform(&mut b).unwrap();
            assert_eq!(a, b, "mismatch for {}", t.type_name());
        }
    }
}
