//! The transformer library — Kamae's configurable, stateless column
//! operations (mathematical, string, date, geographical, logical, array,
//! conditional and hash-indexing families), each exporting a 1:1 GraphSpec
//! op for the compiled inference graph.
//!
//! Fitted estimator models ([`crate::estimators`]) also implement
//! [`crate::pipeline::Transformer`] and register in the same [`load`]
//! registry so pipelines round-trip through JSON regardless of stage kind.

mod array;
mod common;
mod date;
mod indexing;
mod logical;
mod math;
mod misc;
mod string;

pub use array::{
    CosineSimilarityTransformer, ElementAtTransformer, ListAggregateTransformer,
    ListPadTransformer, ListSliceTransformer, VectorAssembleTransformer,
    VectorDisassembleTransformer,
};
pub use common::Io;
pub use date::{
    DateAddTransformer, DateDiffTransformer, DateParseTransformer, DatePartTransformer,
    SecondsToDaysTransformer, TimestampParseTransformer,
};
pub use indexing::{BloomEncodeTransformer, HashIndexTransformer};
pub(crate) use indexing::hash_ref as indexing_hash_ref;
pub use logical::{
    BooleanTransformer, CompareConstantTransformer, CompareTransformer, IfThenElseTransformer,
    IsNullTransformer, NotTransformer, StringEqualsTransformer,
};
pub use math::{
    AbsTransformer, AddConstantTransformer, ArithmeticTransformer, BucketizeTransformer,
    CeilTransformer, ClipTransformer, ColumnsAgg, ColumnsAggTransformer, CosTransformer,
    DivideConstantTransformer, ExpTransformer, FloorTransformer, LogTransformer,
    MultiplyConstantTransformer, NegTransformer, PowerTransformer, ReciprocalTransformer,
    RoundTransformer, ScaleShiftTransformer, SigmoidTransformer, SinTransformer,
    SqrtTransformer, SubtractConstantTransformer, TanhTransformer,
};
pub use misc::{CastTransformer, HaversineTransformer};
pub use string::{
    RegexExtractTransformer, RegexReplaceTransformer, StringCaseTransformer,
    StringConcatTransformer, StringContainsTransformer, StringLengthTransformer,
    StringListToStringTransformer, StringReplaceTransformer, StringToStringListTransformer,
    SubstringTransformer, TrimTransformer,
};

// re-export op enums used in constructors
pub use crate::ops::array::ListAgg;
pub use crate::ops::date::DatePart;
pub use crate::ops::logical::{BoolOp, CmpOp};
pub use crate::ops::math::BinOp;
pub use crate::ops::string_ops::{CaseMode, MatchMode};

use crate::error::{KamaeError, Result};
use crate::pipeline::Transformer;
use crate::util::json::Json;

/// Deserialise any registered transformer (or fitted estimator model)
/// from its `{"type": ..., params...}` JSON form.
pub fn load(j: &Json) -> Result<Box<dyn Transformer>> {
    let type_name = j.req_str("type")?;
    match type_name {
        // math family — all unary ops share one loader keyed by "op"
        "LogTransformer" | "ExpTransformer" | "SqrtTransformer" | "AbsTransformer"
        | "NegTransformer" | "ReciprocalTransformer" | "RoundTransformer" | "FloorTransformer"
        | "CeilTransformer" | "SinTransformer" | "CosTransformer" | "TanhTransformer"
        | "SigmoidTransformer" | "ClipTransformer" | "PowerTransformer"
        | "AddConstantTransformer" | "SubtractConstantTransformer"
        | "MultiplyConstantTransformer" | "DivideConstantTransformer"
        | "ScaleShiftTransformer" | "UnaryMath" => math::unary_from_json(j),
        "ArithmeticTransformer" => math::arithmetic_from_json(j),
        "BucketizeTransformer" => math::bucketize_from_json(j),
        "ColumnsAggTransformer" => math::columns_agg_from_json(j),
        // string family
        "StringCaseTransformer" => string::case_from_json(j),
        "TrimTransformer" => string::trim_from_json(j),
        "SubstringTransformer" => string::substring_from_json(j),
        "StringReplaceTransformer" => string::replace_from_json(j),
        "RegexReplaceTransformer" => string::regex_replace_from_json(j),
        "RegexExtractTransformer" => string::regex_extract_from_json(j),
        "StringConcatTransformer" => string::concat_from_json(j),
        "StringToStringListTransformer" => string::split_from_json(j),
        "StringListToStringTransformer" => string::join_from_json(j),
        "StringContainsTransformer" => string::contains_from_json(j),
        "StringLengthTransformer" => string::str_len_from_json(j),
        // date family
        "DateParseTransformer" => date::date_parse_from_json(j),
        "TimestampParseTransformer" => date::timestamp_parse_from_json(j),
        "DatePartTransformer" => date::date_part_from_json(j),
        "DateDiffTransformer" => date::date_diff_from_json(j),
        "DateAddTransformer" => date::date_add_from_json(j),
        "SecondsToDaysTransformer" => date::seconds_to_days_from_json(j),
        // logical family
        "CompareTransformer" => logical::compare_from_json(j),
        "CompareConstantTransformer" => logical::compare_constant_from_json(j),
        "StringEqualsTransformer" => logical::string_equals_from_json(j),
        "BooleanTransformer" => logical::boolean_from_json(j),
        "NotTransformer" => logical::not_from_json(j),
        "IfThenElseTransformer" => logical::if_then_else_from_json(j),
        "IsNullTransformer" => logical::is_null_from_json(j),
        // array family
        "VectorAssembleTransformer" => array::assemble_from_json(j),
        "VectorDisassembleTransformer" => array::disassemble_from_json(j),
        "ListAggregateTransformer" => array::list_agg_from_json(j),
        "ElementAtTransformer" => array::element_at_from_json(j),
        "ListSliceTransformer" => array::list_slice_from_json(j),
        "ListPadTransformer" => array::list_pad_from_json(j),
        "CosineSimilarityTransformer" => array::cosine_from_json(j),
        // indexing family
        "HashIndexTransformer" => indexing::hash_index_from_json(j),
        "BloomEncodeTransformer" => indexing::bloom_from_json(j),
        // misc
        "HaversineTransformer" => haversine_load(j),
        "CastTransformer" => misc::cast_from_json(j),
        // fitted estimator models
        "StringIndexModel" => crate::estimators::string_index_model_from_json(j),
        "OneHotModel" => crate::estimators::one_hot_model_from_json(j),
        "StandardScaleModel" => crate::estimators::standard_scale_model_from_json(j),
        "MinMaxScaleModel" => crate::estimators::min_max_scale_model_from_json(j),
        "ImputeModel" => crate::estimators::impute_model_from_json(j),
        other => Err(KamaeError::Serde(format!("unknown transformer type: {other}"))),
    }
}

fn haversine_load(j: &Json) -> Result<Box<dyn Transformer>> {
    misc::haversine_from_json(j)
}
