//! Boxed scalar values.
//!
//! `Value` is the row-wise, dynamically typed representation used by the
//! MLeap-like baseline interpreter ([`crate::baselines`]) and by tests. The
//! vectorised engine never touches it on the hot path — that contrast is
//! exactly the paper's "native transformations, not UDFs" performance claim
//! (experiment C2).

use std::fmt;

/// A dynamically typed scalar or list value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    List(Vec<Value>),
}

impl Value {
    /// Numeric coercion mirroring Spark SQL's widening rules.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(x) => Some(*x),
            Value::I64(x) => Some(*x as f64),
            Value::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(x) => Some(*x),
            Value::F64(x) => Some(*x as i64),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::I64(x) => write!(f, "{x}"),
            Value::F64(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::List(vs) => {
                write!(f, "[")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coercions() {
        assert_eq!(Value::I64(3).as_f64(), Some(3.0));
        assert_eq!(Value::Bool(true).as_f64(), Some(1.0));
        assert_eq!(Value::F64(2.7).as_i64(), Some(2));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
        assert!(Value::Null.is_null());
    }

    #[test]
    fn display() {
        let v = Value::List(vec![Value::I64(1), Value::Str("a".into()), Value::Null]);
        assert_eq!(v.to_string(), "[1, a, null]");
    }
}
