//! The DataFrame: an ordered collection of named, equal-length columns.

use std::collections::HashMap;
use std::sync::Arc;

use crate::dataframe::{Column, DType};
use crate::error::{KamaeError, Result};

/// A named, typed column slot in a schema.
#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    pub name: String,
    pub dtype: DType,
}

/// Ordered list of fields.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn field(&self, name: &str) -> Option<&Field> {
        self.fields.iter().find(|f| f.name == name)
    }

    pub fn dtype(&self, name: &str) -> Option<&DType> {
        self.field(name).map(|f| &f.dtype)
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }
}

/// An immutable-by-convention columnar table. Transformers append new
/// columns; existing columns are never mutated in place (Spark semantics —
/// this is what makes pipeline stages freely composable and re-runnable).
/// Columns are `Arc`-shared: cloning a DataFrame (every pipeline stage
/// boundary and every serving request) is O(columns) pointer bumps, not a
/// deep copy — the §Perf L3 optimisation that makes immutable-by-
/// convention semantics affordable.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DataFrame {
    columns: Vec<(String, Arc<Column>)>,
    index: HashMap<String, usize>,
    nrows: usize,
}

impl DataFrame {
    /// Build from named columns; all columns must have equal length.
    pub fn new(columns: Vec<(String, Column)>) -> Result<Self> {
        let mut df = DataFrame::default();
        let mut first = true;
        for (name, col) in columns {
            if first {
                df.nrows = col.len();
                first = false;
            }
            df.push_column(name, col)?;
        }
        Ok(df)
    }

    /// Empty frame with a fixed row count (used when building up columns).
    pub fn with_nrows(nrows: usize) -> Self {
        DataFrame { columns: vec![], index: HashMap::new(), nrows }
    }

    pub fn num_rows(&self) -> usize {
        self.nrows
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    pub fn schema(&self) -> Schema {
        Schema {
            fields: self
                .columns
                .iter()
                .map(|(n, c)| Field { name: n.clone(), dtype: c.dtype() })
                .collect(),
        }
    }

    pub fn column_names(&self) -> Vec<&str> {
        self.columns.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn has_column(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    /// Borrow a column by name.
    pub fn column(&self, name: &str) -> Result<&Column> {
        self.index
            .get(name)
            .map(|&i| self.columns[i].1.as_ref())
            .ok_or_else(|| KamaeError::ColumnNotFound(name.into()))
    }

    /// Shared handle to a column (cheap to clone).
    pub fn column_arc(&self, name: &str) -> Result<Arc<Column>> {
        self.index
            .get(name)
            .map(|&i| Arc::clone(&self.columns[i].1))
            .ok_or_else(|| KamaeError::ColumnNotFound(name.into()))
    }

    /// Append a column. Errors if the name exists or the length disagrees.
    pub fn push_column<S: Into<String>>(&mut self, name: S, col: Column) -> Result<()> {
        let name = name.into();
        if self.index.contains_key(&name) {
            return Err(KamaeError::InvalidConfig(format!("duplicate column: {name}")));
        }
        if !self.columns.is_empty() && col.len() != self.nrows {
            return Err(KamaeError::LengthMismatch {
                left: col.len(),
                right: self.nrows,
                context: format!("push_column({name})"),
            });
        }
        if self.columns.is_empty() {
            self.nrows = col.len();
        }
        self.index.insert(name.clone(), self.columns.len());
        self.columns.push((name, Arc::new(col)));
        Ok(())
    }

    /// Append or replace a column (pipeline outputs overwrite on re-run).
    pub fn set_column<S: Into<String>>(&mut self, name: S, col: Column) -> Result<()> {
        let name = name.into();
        if let Some(&i) = self.index.get(&name) {
            if col.len() != self.nrows {
                return Err(KamaeError::LengthMismatch {
                    left: col.len(),
                    right: self.nrows,
                    context: format!("set_column({name})"),
                });
            }
            self.columns[i].1 = Arc::new(col);
            Ok(())
        } else {
            self.push_column(name, col)
        }
    }

    /// Project to a subset of columns, in the given order (zero-copy).
    pub fn select(&self, names: &[&str]) -> Result<DataFrame> {
        let mut out = DataFrame::with_nrows(self.nrows);
        for &n in names {
            out.push_shared(n.to_string(), self.column_arc(n)?)?;
        }
        Ok(out)
    }

    /// Drop columns by name (ignores missing names, like Spark's drop;
    /// zero-copy).
    pub fn drop(&self, names: &[&str]) -> DataFrame {
        let mut out = DataFrame::with_nrows(self.nrows);
        for (n, c) in &self.columns {
            if !names.contains(&n.as_str()) {
                out.push_shared(n.clone(), Arc::clone(c)).expect("unique names");
            }
        }
        out
    }

    /// Append a shared column handle without copying data.
    pub fn push_shared<S: Into<String>>(&mut self, name: S, col: Arc<Column>) -> Result<()> {
        let name = name.into();
        if self.index.contains_key(&name) {
            return Err(KamaeError::InvalidConfig(format!("duplicate column: {name}")));
        }
        if !self.columns.is_empty() && col.len() != self.nrows {
            return Err(KamaeError::LengthMismatch {
                left: col.len(),
                right: self.nrows,
                context: format!("push_shared({name})"),
            });
        }
        if self.columns.is_empty() {
            self.nrows = col.len();
        }
        self.index.insert(name.clone(), self.columns.len());
        self.columns.push((name, col));
        Ok(())
    }

    /// Rename a column.
    pub fn rename(&mut self, from: &str, to: &str) -> Result<()> {
        let i = *self
            .index
            .get(from)
            .ok_or_else(|| KamaeError::ColumnNotFound(from.into()))?;
        if self.index.contains_key(to) {
            return Err(KamaeError::InvalidConfig(format!("duplicate column: {to}")));
        }
        self.index.remove(from);
        self.index.insert(to.into(), i);
        self.columns[i].0 = to.into();
        Ok(())
    }

    /// Row-range slice (zero-copy would need Arc'd buffers; cloning ranges
    /// is fine for partitioning which happens once per job).
    pub fn slice(&self, start: usize, len: usize) -> DataFrame {
        let cols = self
            .columns
            .iter()
            .map(|(n, c)| (n.clone(), c.slice(start, len)))
            .collect();
        DataFrame::new(cols).expect("slice preserves lengths")
    }

    /// Keep only the rows where `keep[i]` is true (mask compaction: the
    /// ingress validation gate serves a batch minus its quarantined
    /// rows). Kept rows preserve their relative order; the caller keeps
    /// the mask to re-expand per-row results back to original positions.
    pub fn filter_rows(&self, keep: &[bool]) -> Result<DataFrame> {
        if keep.len() != self.nrows {
            return Err(KamaeError::LengthMismatch {
                left: keep.len(),
                right: self.nrows,
                context: "filter_rows".into(),
            });
        }
        let mut out = DataFrame::with_nrows(keep.iter().filter(|&&k| k).count());
        for (name, col) in &self.columns {
            out.push_column(name.clone(), col.filter(keep)?)?;
        }
        Ok(out)
    }

    /// Vertically concatenate frames with identical schemas.
    pub fn concat(frames: &[&DataFrame]) -> Result<DataFrame> {
        let first = frames
            .first()
            .ok_or_else(|| KamaeError::InvalidConfig("concat of zero frames".into()))?;
        let schema = first.schema();
        for f in frames {
            if f.schema() != schema {
                return Err(KamaeError::InvalidConfig(
                    "concat: schema mismatch between frames".into(),
                ));
            }
        }
        let mut cols = Vec::with_capacity(first.num_columns());
        for (name, _) in &first.columns {
            let parts: Vec<&Column> = frames
                .iter()
                .map(|f| f.column(name).expect("schema checked"))
                .collect();
            cols.push((name.clone(), Column::concat(&parts)?));
        }
        DataFrame::new(cols)
    }

    /// Iterate (name, column) pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Column)> {
        self.columns.iter().map(|(n, c)| (n.as_str(), c.as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn df() -> DataFrame {
        DataFrame::new(vec![
            ("a".into(), Column::from_i64(vec![1, 2, 3])),
            ("b".into(), Column::from_str(vec!["x", "y", "z"])),
        ])
        .unwrap()
    }

    #[test]
    fn basic_accessors() {
        let d = df();
        assert_eq!(d.num_rows(), 3);
        assert_eq!(d.num_columns(), 2);
        assert_eq!(d.column_names(), vec!["a", "b"]);
        assert_eq!(d.schema().dtype("a"), Some(&DType::I64));
        assert!(d.column("missing").is_err());
    }

    #[test]
    fn push_rejects_bad_length_and_dup() {
        let mut d = df();
        assert!(d.push_column("c", Column::from_i64(vec![1])).is_err());
        assert!(d.push_column("a", Column::from_i64(vec![1, 2, 3])).is_err());
        assert!(d.push_column("c", Column::from_i64(vec![4, 5, 6])).is_ok());
    }

    #[test]
    fn set_column_replaces() {
        let mut d = df();
        d.set_column("a", Column::from_f64(vec![1.0, 2.0, 3.0])).unwrap();
        assert_eq!(d.schema().dtype("a"), Some(&DType::F64));
        assert_eq!(d.num_columns(), 2);
    }

    #[test]
    fn select_drop_rename() {
        let d = df();
        let s = d.select(&["b"]).unwrap();
        assert_eq!(s.column_names(), vec!["b"]);
        let dr = d.drop(&["b", "nonexistent"]);
        assert_eq!(dr.column_names(), vec!["a"]);
        let mut r = df();
        r.rename("a", "alpha").unwrap();
        assert!(r.column("alpha").is_ok());
        assert!(r.column("a").is_err());
    }

    #[test]
    fn filter_rows_matches_slice_concat_of_kept_runs() {
        let d = df();
        let got = d.filter_rows(&[true, false, true]).unwrap();
        let want = DataFrame::concat(&[&d.slice(0, 1), &d.slice(2, 1)]).unwrap();
        assert_eq!(got, want);
        // all-quarantined: a zero-row frame that keeps its schema
        let none = d.filter_rows(&[false, false, false]).unwrap();
        assert_eq!(none.num_rows(), 0);
        assert_eq!(none.schema(), d.schema());
        // keep-all is identity
        assert_eq!(d.filter_rows(&[true, true, true]).unwrap(), d);
        assert!(d.filter_rows(&[true]).is_err());
    }

    #[test]
    fn slice_concat_roundtrip() {
        let d = df();
        let a = d.slice(0, 1);
        let b = d.slice(1, 2);
        let back = DataFrame::concat(&[&a, &b]).unwrap();
        assert_eq!(back, d);
    }
}
