//! Typed columns with optional null masks and ragged list columns.

use crate::error::{KamaeError, Result};
use crate::dataframe::Value;

/// Data type of a column, mirroring the subset of Spark SQL types Kamae's
/// transformers operate on. One level of list nesting is supported, which
/// covers the paper's "nested-sequence-native" features (e.g. per-item
/// amenity lists in Learning-to-Rank data).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum DType {
    Bool,
    I32,
    I64,
    F32,
    F64,
    Str,
    /// Ragged list of the given element type (no nested lists-of-lists).
    List(Box<DType>),
}

impl DType {
    /// Parse a dtype name as used in transformer configs and GraphSpec JSON
    /// (`"double"`/`"float64"`, `"string"`, `"array<string>"`, ...).
    pub fn parse(s: &str) -> Result<DType> {
        let s = s.trim();
        if let Some(inner) = s.strip_prefix("array<").and_then(|r| r.strip_suffix('>')) {
            return Ok(DType::List(Box::new(DType::parse(inner)?)));
        }
        match s {
            "bool" | "boolean" => Ok(DType::Bool),
            "int" | "int32" | "integer" => Ok(DType::I32),
            "long" | "int64" | "bigint" => Ok(DType::I64),
            "float" | "float32" => Ok(DType::F32),
            "double" | "float64" => Ok(DType::F64),
            "string" | "str" => Ok(DType::Str),
            other => Err(KamaeError::InvalidConfig(format!("unknown dtype: {other}"))),
        }
    }

    /// Canonical name used in GraphSpec JSON (matches the python side).
    pub fn name(&self) -> String {
        match self {
            DType::Bool => "bool".into(),
            DType::I32 => "int32".into(),
            DType::I64 => "int64".into(),
            DType::F32 => "float32".into(),
            DType::F64 => "float64".into(),
            DType::Str => "string".into(),
            DType::List(inner) => format!("array<{}>", inner.name()),
        }
    }

    /// True for the numeric scalar dtypes.
    pub fn is_numeric(&self) -> bool {
        matches!(self, DType::I32 | DType::I64 | DType::F32 | DType::F64)
    }

    /// Element type if this is a list dtype.
    pub fn element(&self) -> Option<&DType> {
        match self {
            DType::List(inner) => Some(inner),
            _ => None,
        }
    }
}

/// Ragged list storage: `offsets.len() == nrows + 1`, row `i` spans
/// `values[offsets[i]..offsets[i+1]]`. This is the Arrow layout — list
/// operations stay vectorised over `values` instead of boxing per row.
#[derive(Debug, Clone, PartialEq)]
pub struct ListColumn<T> {
    pub values: Vec<T>,
    pub offsets: Vec<u32>,
}

impl<T: Clone> ListColumn<T> {
    /// Build from per-row vectors (convenience; prefer building
    /// offsets/values directly in hot paths).
    pub fn from_rows(rows: Vec<Vec<T>>) -> Self {
        let mut offsets = Vec::with_capacity(rows.len() + 1);
        offsets.push(0u32);
        let total: usize = rows.iter().map(|r| r.len()).sum();
        let mut values = Vec::with_capacity(total);
        for row in rows {
            values.extend(row);
            offsets.push(values.len() as u32);
        }
        ListColumn { values, offsets }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slice of row `i`'s elements.
    pub fn row(&self, i: usize) -> &[T] {
        &self.values[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Iterator over row slices.
    pub fn rows(&self) -> impl Iterator<Item = &[T]> {
        self.offsets
            .windows(2)
            .map(move |w| &self.values[w[0] as usize..w[1] as usize])
    }

    /// True if every row has exactly `n` elements (fixed-width list, the
    /// export contract for compiled graphs).
    pub fn is_fixed_width(&self, n: usize) -> bool {
        self.offsets.windows(2).all(|w| (w[1] - w[0]) as usize == n)
    }

    /// Fixed width if all rows agree, else `None`.
    pub fn fixed_width(&self) -> Option<usize> {
        if self.is_empty() {
            return None;
        }
        let w = (self.offsets[1] - self.offsets[0]) as usize;
        if self.is_fixed_width(w) {
            Some(w)
        } else {
            None
        }
    }
}

/// A column of data. Scalar variants carry an optional null mask
/// (`true` = null); list variants are ragged and non-nullable at the list
/// level (matching how Kamae's sequence features behave after padding).
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    Bool(Vec<bool>, Option<Vec<bool>>),
    I32(Vec<i32>, Option<Vec<bool>>),
    I64(Vec<i64>, Option<Vec<bool>>),
    F32(Vec<f32>, Option<Vec<bool>>),
    F64(Vec<f64>, Option<Vec<bool>>),
    Str(Vec<String>, Option<Vec<bool>>),
    ListBool(ListColumn<bool>),
    ListI32(ListColumn<i32>),
    ListI64(ListColumn<i64>),
    ListF32(ListColumn<f32>),
    ListF64(ListColumn<f64>),
    ListStr(ListColumn<String>),
}

impl Column {
    // ---- constructors -----------------------------------------------------

    pub fn from_bool(v: Vec<bool>) -> Self {
        Column::Bool(v, None)
    }
    pub fn from_i32(v: Vec<i32>) -> Self {
        Column::I32(v, None)
    }
    pub fn from_i64(v: Vec<i64>) -> Self {
        Column::I64(v, None)
    }
    pub fn from_f32(v: Vec<f32>) -> Self {
        Column::F32(v, None)
    }
    pub fn from_f64(v: Vec<f64>) -> Self {
        Column::F64(v, None)
    }
    pub fn from_str<S: Into<String>>(v: Vec<S>) -> Self {
        Column::Str(v.into_iter().map(Into::into).collect(), None)
    }
    pub fn from_str_rows<S: Into<String>>(rows: Vec<Vec<S>>) -> Self {
        Column::ListStr(ListColumn::from_rows(
            rows.into_iter()
                .map(|r| r.into_iter().map(Into::into).collect())
                .collect(),
        ))
    }
    pub fn from_f64_rows(rows: Vec<Vec<f64>>) -> Self {
        Column::ListF64(ListColumn::from_rows(rows))
    }
    pub fn from_i64_rows(rows: Vec<Vec<i64>>) -> Self {
        Column::ListI64(ListColumn::from_rows(rows))
    }

    /// Column of nulls-aware optional f64 values.
    pub fn from_f64_opt(v: Vec<Option<f64>>) -> Self {
        let nulls: Vec<bool> = v.iter().map(|x| x.is_none()).collect();
        let data: Vec<f64> = v.into_iter().map(|x| x.unwrap_or(0.0)).collect();
        let mask = if nulls.iter().any(|&n| n) { Some(nulls) } else { None };
        Column::F64(data, mask)
    }

    /// Column of nulls-aware optional strings.
    pub fn from_str_opt(v: Vec<Option<String>>) -> Self {
        let nulls: Vec<bool> = v.iter().map(|x| x.is_none()).collect();
        let data: Vec<String> = v.into_iter().map(|x| x.unwrap_or_default()).collect();
        let mask = if nulls.iter().any(|&n| n) { Some(nulls) } else { None };
        Column::Str(data, mask)
    }

    // ---- basic accessors --------------------------------------------------

    pub fn len(&self) -> usize {
        match self {
            Column::Bool(v, _) => v.len(),
            Column::I32(v, _) => v.len(),
            Column::I64(v, _) => v.len(),
            Column::F32(v, _) => v.len(),
            Column::F64(v, _) => v.len(),
            Column::Str(v, _) => v.len(),
            Column::ListBool(l) => l.len(),
            Column::ListI32(l) => l.len(),
            Column::ListI64(l) => l.len(),
            Column::ListF32(l) => l.len(),
            Column::ListF64(l) => l.len(),
            Column::ListStr(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Column::Bool(..) => DType::Bool,
            Column::I32(..) => DType::I32,
            Column::I64(..) => DType::I64,
            Column::F32(..) => DType::F32,
            Column::F64(..) => DType::F64,
            Column::Str(..) => DType::Str,
            Column::ListBool(_) => DType::List(Box::new(DType::Bool)),
            Column::ListI32(_) => DType::List(Box::new(DType::I32)),
            Column::ListI64(_) => DType::List(Box::new(DType::I64)),
            Column::ListF32(_) => DType::List(Box::new(DType::F32)),
            Column::ListF64(_) => DType::List(Box::new(DType::F64)),
            Column::ListStr(_) => DType::List(Box::new(DType::Str)),
        }
    }

    /// Null mask for scalar columns (`true` = null), if any nulls present.
    pub fn nulls(&self) -> Option<&Vec<bool>> {
        match self {
            Column::Bool(_, n)
            | Column::I32(_, n)
            | Column::I64(_, n)
            | Column::F32(_, n)
            | Column::F64(_, n)
            | Column::Str(_, n) => n.as_ref(),
            _ => None,
        }
    }

    /// Whether row `i` is null.
    pub fn is_null(&self, i: usize) -> bool {
        self.nulls().map(|n| n[i]).unwrap_or(false)
    }

    /// Number of null rows.
    pub fn null_count(&self) -> usize {
        self.nulls().map(|n| n.iter().filter(|&&x| x).count()).unwrap_or(0)
    }

    /// Drop the null mask (used after imputation fills every null).
    pub fn clear_nulls(&mut self) {
        match self {
            Column::Bool(_, n)
            | Column::I32(_, n)
            | Column::I64(_, n)
            | Column::F32(_, n)
            | Column::F64(_, n)
            | Column::Str(_, n) => *n = None,
            _ => {}
        }
    }

    /// Attach a null mask to a scalar column.
    pub fn set_nulls(&mut self, mask: Option<Vec<bool>>) -> Result<()> {
        if let Some(m) = &mask {
            if m.len() != self.len() {
                return Err(KamaeError::LengthMismatch {
                    left: m.len(),
                    right: self.len(),
                    context: "set_nulls".into(),
                });
            }
        }
        match self {
            Column::Bool(_, n)
            | Column::I32(_, n)
            | Column::I64(_, n)
            | Column::F32(_, n)
            | Column::F64(_, n)
            | Column::Str(_, n) => {
                *n = mask;
                Ok(())
            }
            _ => Err(KamaeError::Unsupported("null mask on list column".into())),
        }
    }

    // ---- typed view accessors (used by the op kernels) ---------------------

    pub fn as_bool(&self) -> Result<&[bool]> {
        match self {
            Column::Bool(v, _) => Ok(v),
            other => Err(type_err("bool", other)),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Column::I32(v, _) => Ok(v),
            other => Err(type_err("int32", other)),
        }
    }
    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            Column::I64(v, _) => Ok(v),
            other => Err(type_err("int64", other)),
        }
    }
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Column::F32(v, _) => Ok(v),
            other => Err(type_err("float32", other)),
        }
    }
    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            Column::F64(v, _) => Ok(v),
            other => Err(type_err("float64", other)),
        }
    }
    pub fn as_str(&self) -> Result<&[String]> {
        match self {
            Column::Str(v, _) => Ok(v),
            other => Err(type_err("string", other)),
        }
    }
    pub fn as_list_str(&self) -> Result<&ListColumn<String>> {
        match self {
            Column::ListStr(l) => Ok(l),
            other => Err(type_err("array<string>", other)),
        }
    }
    pub fn as_list_f64(&self) -> Result<&ListColumn<f64>> {
        match self {
            Column::ListF64(l) => Ok(l),
            other => Err(type_err("array<float64>", other)),
        }
    }
    pub fn as_list_i64(&self) -> Result<&ListColumn<i64>> {
        match self {
            Column::ListI64(l) => Ok(l),
            other => Err(type_err("array<int64>", other)),
        }
    }

    /// Value of row `i` (boxed — used by the row-wise MLeap-like baseline
    /// and by tests; never by the vectorised hot path).
    pub fn value(&self, i: usize) -> Value {
        if self.is_null(i) {
            return Value::Null;
        }
        match self {
            Column::Bool(v, _) => Value::Bool(v[i]),
            Column::I32(v, _) => Value::I64(v[i] as i64),
            Column::I64(v, _) => Value::I64(v[i]),
            Column::F32(v, _) => Value::F64(v[i] as f64),
            Column::F64(v, _) => Value::F64(v[i]),
            Column::Str(v, _) => Value::Str(v[i].clone()),
            Column::ListBool(l) => Value::List(l.row(i).iter().map(|&b| Value::Bool(b)).collect()),
            Column::ListI32(l) => Value::List(l.row(i).iter().map(|&x| Value::I64(x as i64)).collect()),
            Column::ListI64(l) => Value::List(l.row(i).iter().map(|&x| Value::I64(x)).collect()),
            Column::ListF32(l) => Value::List(l.row(i).iter().map(|&x| Value::F64(x as f64)).collect()),
            Column::ListF64(l) => Value::List(l.row(i).iter().map(|&x| Value::F64(x)).collect()),
            Column::ListStr(l) => Value::List(l.row(i).iter().map(|s| Value::Str(s.clone())).collect()),
        }
    }

    /// Take rows `range` into a new column (used for partitioning).
    pub fn slice(&self, start: usize, len: usize) -> Column {
        let end = start + len;
        let slice_nulls = |n: &Option<Vec<bool>>| n.as_ref().map(|m| m[start..end].to_vec());
        match self {
            Column::Bool(v, n) => Column::Bool(v[start..end].to_vec(), slice_nulls(n)),
            Column::I32(v, n) => Column::I32(v[start..end].to_vec(), slice_nulls(n)),
            Column::I64(v, n) => Column::I64(v[start..end].to_vec(), slice_nulls(n)),
            Column::F32(v, n) => Column::F32(v[start..end].to_vec(), slice_nulls(n)),
            Column::F64(v, n) => Column::F64(v[start..end].to_vec(), slice_nulls(n)),
            Column::Str(v, n) => Column::Str(v[start..end].to_vec(), slice_nulls(n)),
            Column::ListBool(l) => Column::ListBool(slice_list(l, start, end)),
            Column::ListI32(l) => Column::ListI32(slice_list(l, start, end)),
            Column::ListI64(l) => Column::ListI64(slice_list(l, start, end)),
            Column::ListF32(l) => Column::ListF32(slice_list(l, start, end)),
            Column::ListF64(l) => Column::ListF64(slice_list(l, start, end)),
            Column::ListStr(l) => Column::ListStr(slice_list(l, start, end)),
        }
    }

    /// Keep only the rows where `keep[i]` is true (the mask-compaction
    /// primitive behind ingress row quarantine: the verdict mask from
    /// validation selects the clean rows to serve). The surviving null
    /// mask is dropped entirely when no kept row is null, so a compacted
    /// column compares equal to one built clean from the start.
    pub fn filter(&self, keep: &[bool]) -> Result<Column> {
        if keep.len() != self.len() {
            return Err(KamaeError::LengthMismatch {
                left: keep.len(),
                right: self.len(),
                context: "Column::filter".into(),
            });
        }
        fn pick<T: Clone>(v: &[T], keep: &[bool]) -> Vec<T> {
            v.iter()
                .zip(keep)
                .filter(|(_, &k)| k)
                .map(|(x, _)| x.clone())
                .collect()
        }
        fn pick_nulls(n: &Option<Vec<bool>>, keep: &[bool]) -> Option<Vec<bool>> {
            let mask = n.as_ref()?;
            let kept = pick(mask, keep);
            if kept.iter().any(|&x| x) {
                Some(kept)
            } else {
                None
            }
        }
        fn pick_list<T: Clone>(l: &ListColumn<T>, keep: &[bool]) -> ListColumn<T> {
            let mut values = Vec::new();
            let mut offsets = vec![0u32];
            for (i, &k) in keep.iter().enumerate() {
                if k {
                    values.extend_from_slice(l.row(i));
                    offsets.push(values.len() as u32);
                }
            }
            ListColumn { values, offsets }
        }
        Ok(match self {
            Column::Bool(v, n) => Column::Bool(pick(v, keep), pick_nulls(n, keep)),
            Column::I32(v, n) => Column::I32(pick(v, keep), pick_nulls(n, keep)),
            Column::I64(v, n) => Column::I64(pick(v, keep), pick_nulls(n, keep)),
            Column::F32(v, n) => Column::F32(pick(v, keep), pick_nulls(n, keep)),
            Column::F64(v, n) => Column::F64(pick(v, keep), pick_nulls(n, keep)),
            Column::Str(v, n) => Column::Str(pick(v, keep), pick_nulls(n, keep)),
            Column::ListBool(l) => Column::ListBool(pick_list(l, keep)),
            Column::ListI32(l) => Column::ListI32(pick_list(l, keep)),
            Column::ListI64(l) => Column::ListI64(pick_list(l, keep)),
            Column::ListF32(l) => Column::ListF32(pick_list(l, keep)),
            Column::ListF64(l) => Column::ListF64(pick_list(l, keep)),
            Column::ListStr(l) => Column::ListStr(pick_list(l, keep)),
        })
    }

    /// Concatenate columns of identical dtype (used to merge partitions).
    pub fn concat(cols: &[&Column]) -> Result<Column> {
        let first = cols.first().ok_or_else(|| {
            KamaeError::InvalidConfig("concat of zero columns".into())
        })?;
        let dt = first.dtype();
        for c in cols {
            if c.dtype() != dt {
                return Err(KamaeError::TypeMismatch {
                    expected: dt.name(),
                    found: c.dtype().name(),
                    context: "Column::concat".into(),
                });
            }
        }
        macro_rules! cat_scalar {
            ($variant:ident, $as:ident) => {{
                let total: usize = cols.iter().map(|c| c.len()).sum();
                let mut data = Vec::with_capacity(total);
                let any_nulls = cols.iter().any(|c| c.nulls().is_some());
                let mut nulls: Option<Vec<bool>> =
                    if any_nulls { Some(Vec::with_capacity(total)) } else { None };
                for c in cols {
                    if let Column::$variant(v, n) = c {
                        data.extend_from_slice(v);
                        if let Some(mask) = &mut nulls {
                            match n {
                                Some(m) => mask.extend_from_slice(m),
                                None => mask.extend(std::iter::repeat(false).take(v.len())),
                            }
                        }
                    }
                }
                Ok(Column::$variant(data, nulls))
            }};
        }
        macro_rules! cat_list {
            ($variant:ident) => {{
                let mut values = Vec::new();
                let mut offsets = vec![0u32];
                for c in cols {
                    if let Column::$variant(l) = c {
                        let base = values.len() as u32;
                        values.extend_from_slice(&l.values);
                        offsets.extend(l.offsets[1..].iter().map(|&o| o + base));
                    }
                }
                Ok(Column::$variant(ListColumn { values, offsets }))
            }};
        }
        match dt {
            DType::Bool => cat_scalar!(Bool, as_bool),
            DType::I32 => cat_scalar!(I32, as_i32),
            DType::I64 => cat_scalar!(I64, as_i64),
            DType::F32 => cat_scalar!(F32, as_f32),
            DType::F64 => cat_scalar!(F64, as_f64),
            DType::Str => cat_scalar!(Str, as_str),
            DType::List(inner) => match *inner {
                DType::Bool => cat_list!(ListBool),
                DType::I32 => cat_list!(ListI32),
                DType::I64 => cat_list!(ListI64),
                DType::F32 => cat_list!(ListF32),
                DType::F64 => cat_list!(ListF64),
                DType::Str => cat_list!(ListStr),
                DType::List(_) => Err(KamaeError::Unsupported("nested list concat".into())),
            },
        }
    }
}

/// Row-wise union of several optional null masks (`true` = null): the
/// canonical mask-propagation rule for columnar kernels — a derived row
/// is null when ANY contributing row was. `None` entries contribute
/// nothing; returns `None` when no input carries a mask (so mask-free
/// pipelines stay allocation-free). Masks of different lengths fold by
/// index (shorter masks simply stop contributing), which matches
/// broadcast-style kernels where one operand is a per-row scalar lane.
pub fn union_null_masks(masks: &[Option<&[bool]>]) -> Option<Vec<bool>> {
    let mut out: Option<Vec<bool>> = None;
    for m in masks.iter().flatten() {
        match &mut out {
            None => out = Some(m.to_vec()),
            Some(acc) => {
                if m.len() > acc.len() {
                    acc.resize(m.len(), false);
                }
                for (a, &b) in acc.iter_mut().zip(m.iter()) {
                    *a |= b;
                }
            }
        }
    }
    out
}

fn slice_list<T: Clone>(l: &ListColumn<T>, start: usize, end: usize) -> ListColumn<T> {
    let v_start = l.offsets[start] as usize;
    let v_end = l.offsets[end] as usize;
    let values = l.values[v_start..v_end].to_vec();
    let offsets = l.offsets[start..=end]
        .iter()
        .map(|&o| o - l.offsets[start])
        .collect();
    ListColumn { values, offsets }
}

fn type_err(expected: &str, found: &Column) -> KamaeError {
    KamaeError::TypeMismatch {
        expected: expected.into(),
        found: found.dtype().name(),
        context: "column accessor".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for name in ["bool", "int32", "int64", "float32", "float64", "string", "array<string>", "array<float64>"] {
            let dt = DType::parse(name).unwrap();
            assert_eq!(dt.name(), name);
        }
        assert!(DType::parse("complex").is_err());
        assert_eq!(DType::parse("double").unwrap(), DType::F64);
        assert_eq!(DType::parse("long").unwrap(), DType::I64);
    }

    #[test]
    fn list_column_rows() {
        let l = ListColumn::from_rows(vec![vec![1i64, 2], vec![], vec![3]]);
        assert_eq!(l.len(), 3);
        assert_eq!(l.row(0), &[1, 2]);
        assert_eq!(l.row(1), &[] as &[i64]);
        assert_eq!(l.row(2), &[3]);
        assert_eq!(l.fixed_width(), None);
        let f = ListColumn::from_rows(vec![vec![1i64, 2], vec![3, 4]]);
        assert_eq!(f.fixed_width(), Some(2));
    }

    #[test]
    fn slice_and_concat_scalar() {
        let c = Column::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let a = c.slice(0, 2);
        let b = c.slice(2, 3);
        let back = Column::concat(&[&a, &b]).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn slice_and_concat_list() {
        let c = Column::from_str_rows(vec![vec!["a", "b"], vec!["c"], vec![], vec!["d", "e", "f"]]);
        let a = c.slice(0, 2);
        let b = c.slice(2, 2);
        let back = Column::concat(&[&a, &b]).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn nulls_concat_mixed() {
        let a = Column::from_f64_opt(vec![Some(1.0), None]);
        let b = Column::from_f64(vec![3.0]);
        let c = Column::concat(&[&a, &b]).unwrap();
        assert_eq!(c.null_count(), 1);
        assert!(c.is_null(1));
        assert!(!c.is_null(2));
    }

    #[test]
    fn value_access() {
        let c = Column::from_str_opt(vec![Some("x".into()), None]);
        assert_eq!(c.value(0), Value::Str("x".into()));
        assert_eq!(c.value(1), Value::Null);
    }

    #[test]
    fn union_null_masks_folds_row_wise() {
        // no masks at all -> None (mask-free stays allocation-free)
        assert_eq!(union_null_masks(&[None, None]), None);
        let a = vec![true, false, false];
        let b = vec![false, true, false];
        assert_eq!(
            union_null_masks(&[Some(&a), None, Some(&b)]),
            Some(vec![true, true, false])
        );
        // single mask passes through unchanged
        assert_eq!(union_null_masks(&[Some(&a)]), Some(a.clone()));
        // shorter masks stop contributing past their length
        let short = vec![true];
        assert_eq!(
            union_null_masks(&[Some(&a), Some(&short)]),
            Some(vec![true, false, false])
        );
    }

    #[test]
    fn union_null_masks_validation_gate_shapes() {
        // the shapes the ingress validation gate feeds it: the union of
        // the required columns' masks IS the quarantine pre-mask.
        // All-None (a fully clean batch) must stay allocation-free …
        assert_eq!(union_null_masks(&[None, None, None]), None);
        assert_eq!(union_null_masks(&[]), None);
        // … a longer mask arriving AFTER a shorter one must grow the
        // accumulator instead of truncating the union (unequal lengths
        // in both orders)
        let short = vec![true, false];
        let long = vec![false, false, true, false];
        assert_eq!(
            union_null_masks(&[Some(&short), Some(&long)]),
            Some(vec![true, false, true, false])
        );
        assert_eq!(
            union_null_masks(&[Some(&long), Some(&short)]),
            Some(vec![true, false, true, false])
        );
        // interleaved None entries contribute nothing either side
        assert_eq!(
            union_null_masks(&[None, Some(&short), None, Some(&long), None]),
            Some(vec![true, false, true, false])
        );
    }

    #[test]
    fn filter_compacts_scalars_lists_and_masks() {
        let keep = [true, false, true, false];
        let f = Column::from_f64_opt(vec![Some(1.0), None, Some(3.0), Some(4.0)]);
        // the kept rows carry no null -> the mask is dropped entirely
        assert_eq!(f.filter(&keep).unwrap(), Column::from_f64(vec![1.0, 3.0]));
        // a surviving null keeps (and compacts) the mask
        let g = Column::from_f64_opt(vec![None, Some(2.0), Some(3.0), None]);
        let got = g.filter(&[true, true, false, false]).unwrap();
        assert_eq!(got, Column::from_f64_opt(vec![None, Some(2.0)]));
        // ragged lists re-base their offsets
        let l = Column::from_str_rows(vec![vec!["a", "b"], vec!["c"], vec![], vec!["d"]]);
        assert_eq!(
            l.filter(&keep).unwrap(),
            Column::from_str_rows(vec![vec!["a", "b"], Vec::<&str>::new()])
        );
        // keep-none and keep-all edges
        assert_eq!(f.filter(&[false; 4]).unwrap().len(), 0);
        assert_eq!(
            Column::from_i64(vec![7, 8]).filter(&[true, true]).unwrap(),
            Column::from_i64(vec![7, 8])
        );
        // length mismatch is an error, not a truncation
        assert!(f.filter(&[true]).is_err());
    }
}
