//! In-memory columnar DataFrame — the engine's unit of data.
//!
//! This is the "Spark DataFrame" substrate of the reproduction: typed
//! columns with optional null masks, ragged list columns (Arrow-style
//! offsets + values), a schema, and CSV/JSONL I/O. Transformations are
//! implemented as vectorised kernels over [`Column`]s in [`crate::ops`] —
//! the analogue of Spark's *native* (Catalyst-optimisable) expressions the
//! paper contrasts with slow row-wise UDFs.

mod column;
mod frame;
mod io;
mod value;

pub use column::{union_null_masks, Column, DType, ListColumn};
pub use frame::{DataFrame, Field, Schema};
pub use io::{
    dataframe_from_json_rows, dataframe_from_json_rows_lenient, infer_jsonl_schema, read_csv,
    read_jsonl, read_jsonl_reporting, row_to_json, write_csv, write_jsonl, RowError,
};
pub use value::Value;
