//! CSV / JSONL readers and writers.
//!
//! Minimal but real: schema-driven typed parsing, quoted CSV fields, null
//! handling (empty CSV cell / JSON `null`), list columns in JSONL. Used by
//! the CLI, the examples and the synthetic-data generators.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::dataframe::{Column, DataFrame, DType, Field, ListColumn, Schema};
use crate::error::{KamaeError, Result};
use crate::util::json::Json;

/// One structured data-quality violation on one row: which declarative
/// rule fired, on which column, with a human-readable message. This is
/// the shared error currency of BOTH ingest paths — the lenient file
/// reader ([`read_jsonl_reporting`]) and the serving ingress gate
/// (`serving::validate`) emit the same shape, so offline dead-letter
/// records and online per-row verdicts are directly comparable.
#[derive(Debug, Clone, PartialEq)]
pub struct RowError {
    /// Rule identifier (`"required"`, `"dtype"`, `"not_null"`,
    /// `"range"`, `"one_of"`, `"pattern"`, `"unknown_column"`, `"row"`).
    pub rule: String,
    /// Offending column (empty for whole-row violations).
    pub column: String,
    /// Human-readable description of the violation.
    pub message: String,
}

impl RowError {
    pub fn new<R: Into<String>, C: Into<String>, M: Into<String>>(
        rule: R,
        column: C,
        message: M,
    ) -> Self {
        RowError { rule: rule.into(), column: column.into(), message: message.into() }
    }

    /// Wire shape: `{"rule": ..., "column": ..., "message": ...}`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("rule", self.rule.clone());
        j.set("column", self.column.clone());
        j.set("message", self.message.clone());
        j
    }
}

/// Read a CSV file with a header row, parsing each column per `schema`.
/// Empty cells become nulls (scalar columns only).
pub fn read_csv(path: &Path, schema: &Schema) -> Result<DataFrame> {
    let file = File::open(path)?;
    let mut lines = BufReader::new(file).lines();
    let header = lines
        .next()
        .ok_or_else(|| KamaeError::Serde("empty csv".into()))??;
    let names = split_csv_line(&header);
    let mut builders: Vec<ColumnBuilder> = Vec::with_capacity(names.len());
    for n in &names {
        let dt = schema
            .dtype(n)
            .ok_or_else(|| KamaeError::ColumnNotFound(format!("{n} (in csv header, not schema)")))?;
        builders.push(ColumnBuilder::new(dt.clone()));
    }
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let cells = split_csv_line(&line);
        if cells.len() != names.len() {
            return Err(KamaeError::Serde(format!(
                "csv row has {} cells, header has {}",
                cells.len(),
                names.len()
            )));
        }
        for (b, cell) in builders.iter_mut().zip(cells.iter()) {
            b.push_csv(cell)?;
        }
    }
    let cols = names
        .into_iter()
        .zip(builders)
        .map(|(n, b)| (n, b.finish()))
        .collect();
    DataFrame::new(cols)
}

/// Write a DataFrame as CSV (lists serialised as `|`-joined strings).
pub fn write_csv(df: &DataFrame, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let names = df.column_names();
    writeln!(w, "{}", names.join(","))?;
    for i in 0..df.num_rows() {
        let mut cells = Vec::with_capacity(names.len());
        for (_, col) in df.iter() {
            cells.push(csv_cell(col, i));
        }
        writeln!(w, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Read newline-delimited JSON. The schema drives typing; missing keys and
/// JSON `null` become nulls. Type-mismatched cells are coerced to the
/// column's default exactly as before — use [`read_jsonl_reporting`] to
/// learn WHICH cells were coerced.
pub fn read_jsonl(path: &Path, schema: &Schema) -> Result<DataFrame> {
    Ok(read_jsonl_reporting(path, schema)?.0)
}

/// [`read_jsonl`] plus a record of every cell its leniency papered over:
/// for each row whose non-null value did not fit the column dtype (and
/// was therefore coerced to the builder default), a `(row_index,
/// RowError)` pair with rule `"dtype"` — the same structured shape the
/// serving ingress gate reports, so offline file ingest and online
/// request validation disagree about nothing but transport. The returned
/// frame is bit-identical to what [`read_jsonl`] built before reporting
/// existed.
pub fn read_jsonl_reporting(
    path: &Path,
    schema: &Schema,
) -> Result<(DataFrame, Vec<(usize, RowError)>)> {
    let file = File::open(path)?;
    let mut builders: Vec<(String, ColumnBuilder)> = schema
        .fields
        .iter()
        .map(|f| (f.name.clone(), ColumnBuilder::new(f.dtype.clone())))
        .collect();
    let mut report = Vec::new();
    let mut row = 0usize;
    for line in BufReader::new(file).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let obj = Json::parse(&line)?;
        for ((name, b), f) in builders.iter_mut().zip(schema.fields.iter()) {
            let v = obj.get(name.as_str()).unwrap_or(&Json::Null);
            if !v.is_null() {
                if let Some(msg) = cell_mismatch(v, &f.dtype, name) {
                    report.push((row, RowError::new("dtype", name.as_str(), msg)));
                }
            }
            b.push_json(v)?;
        }
        row += 1;
    }
    let df = DataFrame::new(builders.into_iter().map(|(n, b)| (n, b.finish())).collect())?;
    Ok((df, report))
}

/// Build a DataFrame from already-parsed JSON row objects, typed by
/// `schema` — the in-memory sibling of [`read_jsonl`], used by the
/// network front-end to decode request bodies.
///
/// Unlike the file reader this decoder is STRICT — request bodies are
/// caller mistakes waiting to happen, and a silent zero-fill turns a
/// typo'd column into a wrong prediction. Every violation is a
/// [`KamaeError::Serde`] naming the row index and offending column:
///
/// - a row that is not a JSON object,
/// - a key the schema does not have (usually a typo'd column name),
/// - a schema column the row lacks (explicit `null` is the way to send
///   an intentional null),
/// - a value whose JSON type does not fit the column dtype (floats fit
///   float columns, integers fit both; nothing else coerces).
pub fn dataframe_from_json_rows(rows: &[Json], schema: &Schema) -> Result<DataFrame> {
    let mut builders: Vec<ColumnBuilder> = schema
        .fields
        .iter()
        .map(|f| ColumnBuilder::new(f.dtype.clone()))
        .collect();
    for (i, row) in rows.iter().enumerate() {
        let Some(obj) = row.as_object() else {
            return Err(KamaeError::Serde(format!("row {i} is not a JSON object")));
        };
        for key in obj.keys() {
            if schema.field(key).is_none() {
                return Err(KamaeError::Serde(format!(
                    "row {i} has unknown column '{key}' (schema columns: {})",
                    schema.names().join(", ")
                )));
            }
        }
        for (f, b) in schema.fields.iter().zip(builders.iter_mut()) {
            let Some(v) = row.get(&f.name) else {
                return Err(KamaeError::Serde(format!(
                    "row {i} is missing required column '{}' (send null for an intentional null)",
                    f.name
                )));
            };
            check_json_dtype(v, &f.dtype, &f.name, i)?;
            b.push_json(v)?;
        }
    }
    DataFrame::new(
        schema
            .fields
            .iter()
            .zip(builders)
            .map(|(f, b)| (f.name.clone(), b.finish()))
            .collect(),
    )
}

/// The JSON type name used in strict-decode error messages.
fn json_type_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Int(_) => "integer",
        Json::Float(_) => "number",
        Json::Str(_) => "string",
        Json::Array(_) => "array",
        Json::Object(_) => "object",
    }
}

/// Dtype check for one cell, as a message: `None` means the value fits
/// (`null` fits everything, integers fit both integer and float columns,
/// floats only float columns; strings, bools and arrays only their own
/// dtype, with list elements checked against the element dtype). The
/// strict request decoder turns the message into a hard error; the
/// lenient paths turn it into a [`RowError`].
fn cell_mismatch(v: &Json, dt: &DType, col: &str) -> Option<String> {
    let mismatch = || {
        Some(format!(
            "column '{col}' expects {}, got JSON {}",
            dt.name(),
            json_type_name(v)
        ))
    };
    if v.is_null() {
        return None;
    }
    match dt {
        DType::Bool => match v {
            Json::Bool(_) => None,
            _ => mismatch(),
        },
        DType::I32 | DType::I64 => match v {
            Json::Int(_) => None,
            _ => mismatch(),
        },
        DType::F32 | DType::F64 => match v {
            Json::Int(_) | Json::Float(_) => None,
            _ => mismatch(),
        },
        DType::Str => match v {
            Json::Str(_) => None,
            _ => mismatch(),
        },
        DType::List(inner) => match v {
            Json::Array(items) => {
                for item in items {
                    if item.is_null() {
                        return Some(format!(
                            "column '{col}' expects {}, got a null list element",
                            dt.name()
                        ));
                    }
                    let ok = match inner.as_ref() {
                        DType::Str => matches!(item, Json::Str(_)),
                        DType::I32 | DType::I64 => matches!(item, Json::Int(_)),
                        _ => matches!(item, Json::Int(_) | Json::Float(_)),
                    };
                    if !ok {
                        return Some(format!(
                            "column '{col}' expects {}, got a {} list element",
                            dt.name(),
                            json_type_name(item)
                        ));
                    }
                }
                None
            }
            _ => mismatch(),
        },
    }
}

/// Strict wrapper over [`cell_mismatch`] keeping the request decoder's
/// historical `row {i} column '{col}' ...` error strings byte-identical.
fn check_json_dtype(v: &Json, dt: &DType, col: &str, row: usize) -> Result<()> {
    match cell_mismatch(v, dt, col) {
        Some(msg) => Err(KamaeError::Serde(format!("row {row} {msg}"))),
        None => Ok(()),
    }
}

/// Lenient sibling of [`dataframe_from_json_rows`] for the serving
/// ingress validation gate: instead of failing the whole request on the
/// first bad row, every structural violation becomes a [`RowError`]
/// against its row and the offending cell decodes as null — the
/// downstream columnar rule evaluation then quarantines exactly the rows
/// whose error list is non-empty, and the clean rows decode bit-identical
/// to the strict path. Returned per-row error lists are index-aligned
/// with `rows` (empty list = structurally clean row).
pub fn dataframe_from_json_rows_lenient(
    rows: &[Json],
    schema: &Schema,
) -> Result<(DataFrame, Vec<Vec<RowError>>)> {
    let mut builders: Vec<ColumnBuilder> = schema
        .fields
        .iter()
        .map(|f| ColumnBuilder::new(f.dtype.clone()))
        .collect();
    let mut errors: Vec<Vec<RowError>> = vec![Vec::new(); rows.len()];
    for (i, row) in rows.iter().enumerate() {
        let Some(obj) = row.as_object() else {
            errors[i].push(RowError::new("row", "", "row is not a JSON object"));
            for b in builders.iter_mut() {
                b.push_json(&Json::Null)?;
            }
            continue;
        };
        for key in obj.keys() {
            if schema.field(key).is_none() {
                errors[i].push(RowError::new(
                    "unknown_column",
                    key.as_str(),
                    format!(
                        "unknown column '{key}' (schema columns: {})",
                        schema.names().join(", ")
                    ),
                ));
            }
        }
        for (f, b) in schema.fields.iter().zip(builders.iter_mut()) {
            let Some(v) = row.get(&f.name) else {
                errors[i].push(RowError::new(
                    "required",
                    f.name.as_str(),
                    format!(
                        "missing required column '{}' (send null for an intentional null)",
                        f.name
                    ),
                ));
                b.push_json(&Json::Null)?;
                continue;
            };
            match cell_mismatch(v, &f.dtype, &f.name) {
                Some(msg) => {
                    errors[i].push(RowError::new("dtype", f.name.as_str(), msg));
                    b.push_json(&Json::Null)?;
                }
                None => b.push_json(v)?,
            }
        }
    }
    let df = DataFrame::new(
        schema
            .fields
            .iter()
            .zip(builders)
            .map(|(f, b)| (f.name.clone(), b.finish()))
            .collect(),
    )?;
    Ok((df, errors))
}

/// Render row `i` of a frame as a JSON object (the shape one
/// [`write_jsonl`] line carries). Used by the serving dead-letter sink
/// to quarantine rows that only exist as frame rows.
pub fn row_to_json(df: &DataFrame, i: usize) -> Json {
    let mut obj = Json::object();
    for (name, col) in df.iter() {
        obj.set(name, json_cell(col, i));
    }
    obj
}

/// Write newline-delimited JSON.
pub fn write_jsonl(df: &DataFrame, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..df.num_rows() {
        let mut obj = Json::object();
        for (name, col) in df.iter() {
            obj.set(name, json_cell(col, i));
        }
        writeln!(w, "{}", obj)?;
    }
    Ok(())
}

/// Infer a Schema from the first JSONL record (strings stay strings,
/// numbers become f64, integers i64, arrays become typed lists).
pub fn infer_jsonl_schema(path: &Path) -> Result<Schema> {
    let file = File::open(path)?;
    let first = BufReader::new(file)
        .lines()
        .next()
        .ok_or_else(|| KamaeError::Serde("empty jsonl".into()))??;
    let obj = Json::parse(&first)?;
    let map = obj
        .as_object()
        .ok_or_else(|| KamaeError::Serde("jsonl row is not an object".into()))?;
    let mut fields = Vec::new();
    for (k, v) in map {
        fields.push(Field { name: k.clone(), dtype: infer_dtype(v)? });
    }
    Ok(Schema { fields })
}

fn infer_dtype(v: &Json) -> Result<DType> {
    Ok(match v {
        Json::Bool(_) => DType::Bool,
        Json::Int(_) => DType::I64,
        Json::Float(_) => DType::F64,
        Json::Str(_) => DType::Str,
        Json::Array(items) => {
            let inner = items
                .first()
                .map(infer_dtype)
                .transpose()?
                .unwrap_or(DType::Str);
            DType::List(Box::new(inner))
        }
        Json::Null => DType::F64, // least-bad default
        Json::Object(_) => {
            return Err(KamaeError::Unsupported("nested objects in jsonl".into()))
        }
    })
}

// ---------------------------------------------------------------------------
// builders

enum ColumnBuilder {
    Bool(Vec<bool>, Vec<bool>),
    I32(Vec<i32>, Vec<bool>),
    I64(Vec<i64>, Vec<bool>),
    F32(Vec<f32>, Vec<bool>),
    F64(Vec<f64>, Vec<bool>),
    Str(Vec<String>, Vec<bool>),
    ListStr(ListColumn<String>),
    ListI64(ListColumn<i64>),
    ListF64(ListColumn<f64>),
}

impl ColumnBuilder {
    fn new(dt: DType) -> Self {
        match dt {
            DType::Bool => ColumnBuilder::Bool(vec![], vec![]),
            DType::I32 => ColumnBuilder::I32(vec![], vec![]),
            DType::I64 => ColumnBuilder::I64(vec![], vec![]),
            DType::F32 => ColumnBuilder::F32(vec![], vec![]),
            DType::F64 => ColumnBuilder::F64(vec![], vec![]),
            DType::Str => ColumnBuilder::Str(vec![], vec![]),
            DType::List(inner) => match *inner {
                DType::Str => ColumnBuilder::ListStr(ListColumn { values: vec![], offsets: vec![0] }),
                DType::I64 | DType::I32 => {
                    ColumnBuilder::ListI64(ListColumn { values: vec![], offsets: vec![0] })
                }
                _ => ColumnBuilder::ListF64(ListColumn { values: vec![], offsets: vec![0] }),
            },
        }
    }

    fn push_csv(&mut self, cell: &str) -> Result<()> {
        let null = cell.is_empty();
        macro_rules! scalar {
            ($data:expr, $nulls:expr, $parse:expr, $default:expr) => {{
                $nulls.push(null);
                if null {
                    $data.push($default);
                } else {
                    $data.push($parse.map_err(|_| {
                        KamaeError::Serde(format!("cannot parse csv cell: {cell:?}"))
                    })?);
                }
            }};
        }
        match self {
            ColumnBuilder::Bool(d, n) => scalar!(d, n, cell.parse::<bool>(), false),
            ColumnBuilder::I32(d, n) => scalar!(d, n, cell.parse::<i32>(), 0),
            ColumnBuilder::I64(d, n) => scalar!(d, n, cell.parse::<i64>(), 0),
            ColumnBuilder::F32(d, n) => scalar!(d, n, cell.parse::<f32>(), 0.0),
            ColumnBuilder::F64(d, n) => scalar!(d, n, cell.parse::<f64>(), 0.0),
            ColumnBuilder::Str(d, n) => {
                n.push(null);
                d.push(cell.to_string());
            }
            // list columns in CSV: `|`-separated (MovieLens genre style)
            ColumnBuilder::ListStr(l) => {
                if !null {
                    l.values.extend(cell.split('|').map(str::to_string));
                }
                l.offsets.push(l.values.len() as u32);
            }
            ColumnBuilder::ListI64(l) => {
                if !null {
                    for p in cell.split('|') {
                        l.values.push(p.parse::<i64>().map_err(|_| {
                            KamaeError::Serde(format!("cannot parse csv list cell: {cell:?}"))
                        })?);
                    }
                }
                l.offsets.push(l.values.len() as u32);
            }
            ColumnBuilder::ListF64(l) => {
                if !null {
                    for p in cell.split('|') {
                        l.values.push(p.parse::<f64>().map_err(|_| {
                            KamaeError::Serde(format!("cannot parse csv list cell: {cell:?}"))
                        })?);
                    }
                }
                l.offsets.push(l.values.len() as u32);
            }
        }
        Ok(())
    }

    fn push_json(&mut self, v: &Json) -> Result<()> {
        let null = v.is_null();
        match self {
            ColumnBuilder::Bool(d, n) => {
                n.push(null);
                d.push(v.as_bool().unwrap_or(false));
            }
            ColumnBuilder::I32(d, n) => {
                n.push(null);
                d.push(v.as_i64().unwrap_or(0) as i32);
            }
            ColumnBuilder::I64(d, n) => {
                n.push(null);
                d.push(v.as_i64().unwrap_or(0));
            }
            ColumnBuilder::F32(d, n) => {
                n.push(null);
                d.push(v.as_f64().unwrap_or(0.0) as f32);
            }
            ColumnBuilder::F64(d, n) => {
                n.push(null);
                d.push(v.as_f64().unwrap_or(0.0));
            }
            ColumnBuilder::Str(d, n) => {
                n.push(null);
                d.push(v.as_str().unwrap_or("").to_string());
            }
            ColumnBuilder::ListStr(l) => {
                if let Some(items) = v.as_array() {
                    l.values
                        .extend(items.iter().map(|x| x.as_str().unwrap_or("").to_string()));
                }
                l.offsets.push(l.values.len() as u32);
            }
            ColumnBuilder::ListI64(l) => {
                if let Some(items) = v.as_array() {
                    l.values.extend(items.iter().map(|x| x.as_i64().unwrap_or(0)));
                }
                l.offsets.push(l.values.len() as u32);
            }
            ColumnBuilder::ListF64(l) => {
                if let Some(items) = v.as_array() {
                    l.values.extend(items.iter().map(|x| x.as_f64().unwrap_or(0.0)));
                }
                l.offsets.push(l.values.len() as u32);
            }
        }
        Ok(())
    }

    fn finish(self) -> Column {
        fn mask(nulls: Vec<bool>) -> Option<Vec<bool>> {
            if nulls.iter().any(|&n| n) {
                Some(nulls)
            } else {
                None
            }
        }
        match self {
            ColumnBuilder::Bool(d, n) => Column::Bool(d, mask(n)),
            ColumnBuilder::I32(d, n) => Column::I32(d, mask(n)),
            ColumnBuilder::I64(d, n) => Column::I64(d, mask(n)),
            ColumnBuilder::F32(d, n) => Column::F32(d, mask(n)),
            ColumnBuilder::F64(d, n) => Column::F64(d, mask(n)),
            ColumnBuilder::Str(d, n) => Column::Str(d, mask(n)),
            ColumnBuilder::ListStr(l) => Column::ListStr(l),
            ColumnBuilder::ListI64(l) => Column::ListI64(l),
            ColumnBuilder::ListF64(l) => Column::ListF64(l),
        }
    }
}

/// Split one CSV line honouring double-quoted fields with `""` escapes.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                cells.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    cells.push(cur);
    cells
}

fn csv_cell(col: &Column, i: usize) -> String {
    use crate::dataframe::Value;
    if col.is_null(i) {
        return String::new();
    }
    match col.value(i) {
        Value::List(vs) => vs
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("|"),
        Value::Str(s) => {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s
            }
        }
        v => v.to_string(),
    }
}

fn json_cell(col: &Column, i: usize) -> Json {
    use crate::dataframe::Value;
    fn conv(v: Value) -> Json {
        match v {
            Value::Null => Json::Null,
            Value::Bool(b) => Json::Bool(b),
            Value::I64(x) => Json::Int(x),
            Value::F64(x) => Json::Float(x),
            Value::Str(s) => Json::Str(s),
            Value::List(vs) => Json::Array(vs.into_iter().map(conv).collect()),
        }
    }
    conv(col.value(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let df = DataFrame::new(vec![
            ("id".into(), Column::from_i64(vec![1, 2])),
            ("name".into(), Column::from_str(vec!["a,b", "c\"d"])),
            ("score".into(), Column::from_f64_opt(vec![Some(1.5), None])),
            ("genres".into(), Column::from_str_rows(vec![vec!["x", "y"], vec!["z"]])),
        ])
        .unwrap();
        let tmp = std::env::temp_dir().join("kamae_io_test.csv");
        write_csv(&df, &tmp).unwrap();
        let back = read_csv(&tmp, &df.schema()).unwrap();
        assert_eq!(back.column("id").unwrap(), df.column("id").unwrap());
        assert_eq!(back.column("name").unwrap(), df.column("name").unwrap());
        assert!(back.column("score").unwrap().is_null(1));
        assert_eq!(back.column("genres").unwrap(), df.column("genres").unwrap());
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn jsonl_roundtrip_and_inference() {
        let df = DataFrame::new(vec![
            ("id".into(), Column::from_i64(vec![10, 20])),
            ("price".into(), Column::from_f64(vec![1.25, 2.5])),
            ("tags".into(), Column::from_str_rows(vec![vec!["a"], vec!["b", "c"]])),
        ])
        .unwrap();
        let tmp = std::env::temp_dir().join("kamae_io_test.jsonl");
        write_jsonl(&df, &tmp).unwrap();
        let schema = infer_jsonl_schema(&tmp).unwrap();
        assert_eq!(schema.dtype("id"), Some(&DType::I64));
        assert_eq!(schema.dtype("tags"), Some(&DType::List(Box::new(DType::Str))));
        let back = read_jsonl(&tmp, &df.schema()).unwrap();
        assert_eq!(back, df);
        std::fs::remove_file(tmp).ok();
    }

    fn request_schema() -> Schema {
        Schema {
            fields: vec![
                Field { name: "price".into(), dtype: DType::F64 },
                Field { name: "city".into(), dtype: DType::Str },
                Field { name: "tags".into(), dtype: DType::List(Box::new(DType::Str)) },
            ],
        }
    }

    #[test]
    fn json_rows_decode_with_schema_typing() {
        let schema = request_schema();
        let rows = vec![
            Json::parse(r#"{"price": 12.5, "city": "berlin", "tags": ["a", "b"]}"#).unwrap(),
            // integer-valued JSON numbers land in f64 columns; explicit
            // null is how a request sends a null cell
            Json::parse(r#"{"price": 99, "city": null, "tags": []}"#).unwrap(),
        ];
        let df = dataframe_from_json_rows(&rows, &schema).unwrap();
        assert_eq!(df.num_rows(), 2);
        assert_eq!(df.column("price").unwrap().as_f64().unwrap(), &[12.5, 99.0]);
        assert!(df.column("city").unwrap().is_null(1));
        assert!(!df.column("city").unwrap().is_null(0));
        // a non-object row errors with its index, not a panic
        let bad = vec![Json::parse("[1, 2]").unwrap()];
        let err = dataframe_from_json_rows(&bad, &schema).unwrap_err();
        assert!(err.to_string().contains("row 0"), "{err}");
    }

    #[test]
    fn json_rows_reject_wrong_dtype_naming_the_column() {
        let schema = request_schema();
        // (body, offending column, what the message must mention)
        let cases = [
            (r#"{"price": "cheap", "city": "berlin", "tags": []}"#, "price", "float64"),
            (r#"{"price": 1.0, "city": 7, "tags": []}"#, "city", "string"),
            (r#"{"price": 1.0, "city": "x", "tags": "a,b"}"#, "tags", "array<string>"),
            (r#"{"price": 1.0, "city": "x", "tags": [1, 2]}"#, "tags", "list element"),
            (r#"{"price": 1.0, "city": "x", "tags": [null]}"#, "tags", "null list element"),
            (r#"{"price": true, "city": "x", "tags": []}"#, "price", "bool"),
        ];
        for (body, col, mention) in cases {
            let rows = vec![Json::parse(body).unwrap()];
            let err = dataframe_from_json_rows(&rows, &schema).unwrap_err().to_string();
            assert!(err.contains(&format!("column '{col}'")), "{body}: {err}");
            assert!(err.contains("row 0"), "{body}: {err}");
            assert!(err.contains(mention), "{body}: {err}");
        }
        // integer dtypes refuse floats (silent truncation is a wrong answer)
        let int_schema = Schema {
            fields: vec![Field { name: "n".into(), dtype: DType::I64 }],
        };
        let rows = vec![Json::parse(r#"{"n": 1.5}"#).unwrap()];
        let err = dataframe_from_json_rows(&rows, &int_schema).unwrap_err().to_string();
        assert!(err.contains("column 'n'") && err.contains("int64"), "{err}");
    }

    #[test]
    fn json_rows_reject_missing_and_unknown_columns() {
        let schema = request_schema();
        // missing required column, named, with the null hint
        let rows = vec![
            Json::parse(r#"{"price": 1.0, "city": "a", "tags": []}"#).unwrap(),
            Json::parse(r#"{"price": 2.0, "tags": []}"#).unwrap(),
        ];
        let err = dataframe_from_json_rows(&rows, &schema).unwrap_err().to_string();
        assert!(err.contains("row 1"), "{err}");
        assert!(err.contains("missing required column 'city'"), "{err}");
        // unknown extra column, named, with the schema listed
        let rows = vec![
            Json::parse(r#"{"price": 1.0, "city": "a", "tags": [], "pricee": 2.0}"#).unwrap(),
        ];
        let err = dataframe_from_json_rows(&rows, &schema).unwrap_err().to_string();
        assert!(err.contains("unknown column 'pricee'"), "{err}");
        assert!(err.contains("price, city, tags"), "{err}");
        // explicit null is NOT a missing column
        let rows = vec![Json::parse(r#"{"price": null, "city": null, "tags": null}"#).unwrap()];
        let df = dataframe_from_json_rows(&rows, &schema).unwrap();
        assert!(df.column("price").unwrap().is_null(0));
    }

    #[test]
    fn lenient_rows_decode_clean_rows_identically_and_report_the_rest() {
        let schema = request_schema();
        let rows = vec![
            Json::parse(r#"{"price": 12.5, "city": "berlin", "tags": ["a"]}"#).unwrap(),
            // three violations on one row: bad dtype, missing column,
            // unknown column
            Json::parse(r#"{"price": "cheap", "tags": [], "pricee": 1.0}"#).unwrap(),
            Json::parse("[1]").unwrap(), // not an object
            Json::parse(r#"{"price": 7, "city": null, "tags": []}"#).unwrap(),
        ];
        let (df, errors) = dataframe_from_json_rows_lenient(&rows, &schema).unwrap();
        assert_eq!(df.num_rows(), 4);
        assert!(errors[0].is_empty());
        let rules: Vec<&str> = errors[1].iter().map(|e| e.rule.as_str()).collect();
        assert!(rules.contains(&"dtype"), "{rules:?}");
        assert!(rules.contains(&"required"), "{rules:?}");
        assert!(rules.contains(&"unknown_column"), "{rules:?}");
        let dt = errors[1].iter().find(|e| e.rule == "dtype").unwrap();
        assert_eq!(dt.column, "price");
        assert!(dt.message.contains("expects float64"), "{}", dt.message);
        // the bad cell decoded as null, not a silent 0.0
        assert!(df.column("price").unwrap().is_null(1));
        assert_eq!(errors[2], vec![RowError::new("row", "", "row is not a JSON object")]);
        // explicit null is NOT an error in the lenient decoder either
        assert!(errors[3].is_empty());
        // clean rows decode bit-identical to the strict decoder
        let strict = dataframe_from_json_rows(&[rows[0].clone(), rows[3].clone()], &schema).unwrap();
        let keep = [true, false, false, true];
        assert_eq!(df.filter_rows(&keep).unwrap(), strict);
    }

    #[test]
    fn read_jsonl_reporting_flags_coerced_cells_with_frames_unchanged() {
        let schema = Schema {
            fields: vec![
                Field { name: "n".into(), dtype: DType::I64 },
                Field { name: "s".into(), dtype: DType::Str },
            ],
        };
        let tmp = std::env::temp_dir().join("kamae_io_lenient_report.jsonl");
        std::fs::write(
            &tmp,
            concat!(
                "{\"n\": 1, \"s\": \"ok\"}\n",
                "{\"n\": \"oops\", \"s\": \"bad-int\"}\n",
                "\n",
                "{\"s\": \"missing-n-is-legal\"}\n",
                "{\"n\": 3, \"s\": 9}\n",
            ),
        )
        .unwrap();
        let (df, report) = read_jsonl_reporting(&tmp, &schema).unwrap();
        // the frame is exactly what the lenient reader always built
        assert_eq!(read_jsonl(&tmp, &schema).unwrap(), df);
        assert_eq!(df.num_rows(), 4);
        // two coerced cells, named with row + rule + column
        assert_eq!(report.len(), 2);
        assert_eq!(report[0].0, 1);
        assert_eq!(report[0].1.rule, "dtype");
        assert_eq!(report[0].1.column, "n");
        assert!(report[0].1.message.contains("expects int64"), "{}", report[0].1.message);
        assert_eq!(report[1].0, 3);
        assert_eq!(report[1].1.column, "s");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn row_error_json_shape() {
        let e = RowError::new("range", "price", "price -1 below minimum 0");
        let j = e.to_json();
        assert_eq!(j.get("rule").and_then(Json::as_str), Some("range"));
        assert_eq!(j.get("column").and_then(Json::as_str), Some("price"));
        assert_eq!(
            j.get("message").and_then(Json::as_str),
            Some("price -1 below minimum 0")
        );
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn csv_quoting() {
        assert_eq!(split_csv_line("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(split_csv_line("\"a,b\",c"), vec!["a,b", "c"]);
        assert_eq!(split_csv_line("\"a\"\"b\",c"), vec!["a\"b", "c"]);
        assert_eq!(split_csv_line("a,,c"), vec!["a", "", "c"]);
    }
}
