//! The op registry — the single source of truth for the GraphSpec op
//! vocabulary.
//!
//! Every op name that can appear in a spec is declared here once, with
//! the metadata the optimizer (and any other spec consumer) needs:
//! which section it belongs to, its input arity, whether it is pure
//! (safe to deduplicate / dead-code-eliminate) and whether the
//! interpreter rounds its float output through f32 (the compiled graph
//! computes in f32; the interpreter emulates that per op — passes that
//! *remove* an op must know whether they are removing a rounding step).
//!
//! Emission sites (`SpecBuilder`, transformers, estimators) reference
//! the [`names`] constants instead of scattering string literals; the
//! tests at the bottom assert that every op the builder can emit is
//! known to both the registry and [`crate::export::SpecInterpreter`].

use crate::error::{KamaeError, Result};
use crate::export::{GraphSpec, SpecNode};
use crate::util::json::Json;

/// Canonical op-name constants. `rust/src/export/interp.rs` and
/// `python/compile/model.py` implement exactly this vocabulary.
pub mod names {
    // ---- ingress (string-side) ops ------------------------------------
    pub const HASH64: &str = "hash64";
    pub const CASE: &str = "case";
    pub const TRIM: &str = "trim";
    pub const SUBSTRING: &str = "substring";
    pub const REPLACE: &str = "replace";
    pub const REGEX_REPLACE: &str = "regex_replace";
    pub const REGEX_EXTRACT: &str = "regex_extract";
    pub const CONCAT: &str = "concat";
    pub const SPLIT_PAD: &str = "split_pad";
    pub const JOIN: &str = "join";
    pub const STRING_MATCH: &str = "string_match";
    pub const STR_LEN: &str = "str_len";
    pub const DATE_TO_DAYS: &str = "date_to_days";
    pub const TIMESTAMP_TO_SECONDS: &str = "timestamp_to_seconds";
    pub const PAD_LIST: &str = "pad_list";
    pub const TO_STRING: &str = "to_string";
    pub const PARSE_NUMBER: &str = "parse_number";
    /// Fused ingress chain (produced by `optim::passes::IngressFuse`,
    /// never by the builder). `attrs.steps` replays the original
    /// single-input op sequence in order; the interpreter executes the
    /// common scalar string chains as one walk over the column.
    pub const FUSED_INGRESS: &str = "fused_ingress";

    // ---- graph (numeric) ops ------------------------------------------
    pub const IDENTITY: &str = "identity";
    pub const TO_F32: &str = "to_f32";
    pub const TO_I64: &str = "to_i64";
    pub const LOG: &str = "log";
    pub const LOG1P: &str = "log1p";
    pub const EXP: &str = "exp";
    pub const SQRT: &str = "sqrt";
    pub const ABS: &str = "abs";
    pub const NEG: &str = "neg";
    pub const RECIPROCAL: &str = "reciprocal";
    pub const ROUND: &str = "round";
    pub const FLOOR: &str = "floor";
    pub const CEIL: &str = "ceil";
    pub const SIN: &str = "sin";
    pub const COS: &str = "cos";
    pub const TANH: &str = "tanh";
    pub const SIGMOID: &str = "sigmoid";
    pub const CLIP: &str = "clip";
    pub const POW_SCALAR: &str = "pow_scalar";
    pub const ADD_SCALAR: &str = "add_scalar";
    pub const SUB_SCALAR: &str = "sub_scalar";
    pub const MUL_SCALAR: &str = "mul_scalar";
    pub const DIV_SCALAR: &str = "div_scalar";
    pub const SCALE_SHIFT: &str = "scale_shift";
    /// Fused scalar-affine chain (produced by the optimizer, never by
    /// the builder). `attrs.steps` replays the original chain exactly;
    /// `attrs.scale`/`attrs.shift` carry the collapsed form for kernels.
    pub const AFFINE: &str = "affine";
    pub const ADD: &str = "add";
    pub const SUB: &str = "sub";
    pub const MUL: &str = "mul";
    pub const DIV: &str = "div";
    pub const POW: &str = "pow";
    pub const MIN: &str = "min";
    pub const MAX: &str = "max";
    pub const MOD: &str = "mod";
    pub const BUCKETIZE: &str = "bucketize";
    /// Fused `compare_scalar(bucketize(x))` ladder (produced by
    /// `optim::passes::BucketizeMerge`): one sorted-splits binary search
    /// feeding the threshold compare directly, instead of materialising
    /// the intermediate bucket-index column.
    pub const MULTI_BUCKETIZE: &str = "multi_bucketize";
    pub const COLUMNS_AGG: &str = "columns_agg";
    pub const DATE_PART: &str = "date_part";
    pub const SUB_I64: &str = "sub_i64";
    pub const ADD_SCALAR_I64: &str = "add_scalar_i64";
    pub const FLOORDIV_SCALAR_I64: &str = "floordiv_scalar_i64";
    pub const COMPARE: &str = "compare";
    pub const COMPARE_SCALAR: &str = "compare_scalar";
    pub const EQ_HASH: &str = "eq_hash";
    pub const BOOL_OP: &str = "bool_op";
    pub const NOT: &str = "not";
    pub const SELECT: &str = "select";
    /// Fused `select(compare_scalar(x), a, b)` (produced by
    /// `optim::passes::SelectCmpFuse`): the predicate is evaluated inside
    /// the select — branchless under the compiled lowering — so the
    /// intermediate i64 mask column is never materialised.
    pub const SELECT_CMP: &str = "select_cmp";
    pub const IS_NAN: &str = "is_nan";
    pub const ASSEMBLE: &str = "assemble";
    pub const VECTOR_AT: &str = "vector_at";
    pub const LIST_SUM: &str = "list_sum";
    pub const LIST_MEAN: &str = "list_mean";
    pub const LIST_MIN: &str = "list_min";
    pub const LIST_MAX: &str = "list_max";
    pub const LIST_LEN: &str = "list_len";
    pub const HASH_BUCKET: &str = "hash_bucket";
    pub const BLOOM_ENCODE: &str = "bloom_encode";
    pub const VOCAB_LOOKUP: &str = "vocab_lookup";
    pub const ONE_HOT: &str = "one_hot";
    pub const SCALE_VEC: &str = "scale_vec";
    pub const IMPUTE: &str = "impute";
    pub const COSINE_SIMILARITY: &str = "cosine_similarity";
    pub const HAVERSINE: &str = "haversine";

    // ---- ops usable in either section ---------------------------------
    pub const ELEMENT_AT: &str = "element_at";
    pub const SLICE_LIST: &str = "slice_list";
}

/// Which spec section an op may appear in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Section {
    /// String-side, executed by the Rust ingress.
    Ingress,
    /// Numeric, compiled/interpreted graph section.
    Graph,
    /// Valid in both sections (list addressing works on strings too).
    Both,
}

impl Section {
    pub fn allows_ingress(&self) -> bool {
        matches!(self, Section::Ingress | Section::Both)
    }

    pub fn allows_graph(&self) -> bool {
        matches!(self, Section::Graph | Section::Both)
    }
}

/// Input arity of an op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arity {
    Exact(usize),
    AtLeast(usize),
}

impl Arity {
    pub fn accepts(&self, n: usize) -> bool {
        match self {
            Arity::Exact(k) => n == *k,
            Arity::AtLeast(k) => n >= *k,
        }
    }
}

/// Registry entry for one op.
#[derive(Debug, Clone, Copy)]
pub struct OpInfo {
    pub name: &'static str,
    pub section: Section,
    pub arity: Arity,
    /// Deterministic and side-effect free: safe for CSE and DCE. (All
    /// current ops are pure; the flag exists so a future stateful op —
    /// e.g. a request-counter feature — degrades the optimizer safely.)
    pub pure: bool,
    /// The interpreter rounds this op's float output through f32 (to
    /// match the compiled graph's f32 arithmetic). A pass may only fold
    /// away such an op when its input is already f32-rounded, otherwise
    /// it would *remove* a rounding step and change downstream bits.
    pub rounds_f32: bool,
    /// Member of the scalar-affine family fusable into [`names::AFFINE`].
    pub affine: bool,
    /// The op may declare named output lanes
    /// ([`crate::export::SpecLane`]) — consumers then reference
    /// `"<id>.<lane>"` or the lane's bare name. Nodes of every other op
    /// must keep `lanes` empty ([`lint_spec`] enforces this).
    pub multi_output: bool,
    /// Estimated per-row work in abstract cost units (the registry half
    /// of the optimizer's cost model — see [`node_cost`]). Relative
    /// magnitudes are what matter: string processing > table lookups >
    /// scalar math > moves.
    pub work: u32,
}

/// Coarse throughput class of an op's kernel-program body
/// ([`crate::export::SpecInterpreter`] compiles specs into columnar
/// kernels at backend load). Derived from the same [`OpInfo::work`]
/// estimate [`node_cost`] charges — the classification introduces no new
/// numbers, it buckets the existing ones for consumers that only need
/// "tight loop vs heavy body" (scheduling heuristics, bench reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Straight-line columnar arithmetic over dense buffers: casts,
    /// unary/binary float math, compares, selects, small gathers. The
    /// kernel body is a branch-light per-row loop.
    Fast,
    /// Table-, search-, or allocation-heavy body: string processing
    /// (all ingress ops), vocab/bloom/one-hot lookups, trig-heavy
    /// geo math. Per-row cost is dominated by memory traffic or
    /// per-element work, not loop overhead.
    Slow,
}

impl OpInfo {
    /// Override the default work estimate (const-friendly builder).
    const fn work(mut self, w: u32) -> OpInfo {
        self.work = w;
        self
    }

    /// Mark the op as able to declare output lanes.
    const fn multi(mut self) -> OpInfo {
        self.multi_output = true;
        self
    }

    /// Classify this op's kernel-program body. Ingress (string-side)
    /// ops are always [`KernelClass::Slow`]; graph ops are bucketed by
    /// their registry work estimate so the split stays consistent with
    /// [`node_cost`] without duplicating per-op judgement calls.
    pub fn kernel_class(&self) -> KernelClass {
        if matches!(self.section, Section::Ingress) || self.work >= 6 {
            KernelClass::Slow
        } else {
            KernelClass::Fast
        }
    }
}

const fn ingress(name: &'static str, arity: Arity) -> OpInfo {
    OpInfo {
        name,
        section: Section::Ingress,
        arity,
        pure: true,
        rounds_f32: false,
        affine: false,
        multi_output: false,
        work: 6,
    }
}

const fn graph(name: &'static str, arity: Arity, rounds_f32: bool) -> OpInfo {
    OpInfo {
        name,
        section: Section::Graph,
        arity,
        pure: true,
        rounds_f32,
        affine: false,
        multi_output: false,
        work: 2,
    }
}

const fn graph_affine(name: &'static str) -> OpInfo {
    OpInfo {
        name,
        section: Section::Graph,
        arity: Arity::Exact(1),
        pure: true,
        rounds_f32: true,
        affine: true,
        multi_output: false,
        work: 2,
    }
}

const fn both(name: &'static str) -> OpInfo {
    OpInfo {
        name,
        section: Section::Both,
        arity: Arity::Exact(1),
        pure: true,
        rounds_f32: false,
        affine: false,
        multi_output: false,
        work: 2,
    }
}

/// The full op vocabulary.
pub const OPS: &[OpInfo] = &[
    // ---- ingress ------------------------------------------------------
    ingress(names::HASH64, Arity::Exact(1)).work(8),
    ingress(names::CASE, Arity::Exact(1)),
    ingress(names::TRIM, Arity::Exact(1)),
    ingress(names::SUBSTRING, Arity::Exact(1)),
    ingress(names::REPLACE, Arity::Exact(1)).work(8),
    ingress(names::REGEX_REPLACE, Arity::Exact(1)).work(24),
    ingress(names::REGEX_EXTRACT, Arity::Exact(1)).work(20),
    ingress(names::CONCAT, Arity::AtLeast(1)).work(8),
    ingress(names::SPLIT_PAD, Arity::Exact(1)).work(12),
    ingress(names::JOIN, Arity::Exact(1)).work(8),
    ingress(names::STRING_MATCH, Arity::Exact(1)),
    ingress(names::STR_LEN, Arity::Exact(1)).work(3),
    ingress(names::DATE_TO_DAYS, Arity::Exact(1)).work(10),
    ingress(names::TIMESTAMP_TO_SECONDS, Arity::Exact(1)).work(10),
    ingress(names::PAD_LIST, Arity::Exact(1)),
    ingress(names::TO_STRING, Arity::Exact(1)),
    ingress(names::PARSE_NUMBER, Arity::Exact(1)),
    // fused chain: work is steps-dependent, see node_cost
    ingress(names::FUSED_INGRESS, Arity::Exact(1)),
    // ---- graph: identity / casts --------------------------------------
    graph(names::IDENTITY, Arity::Exact(1), false).work(0),
    graph(names::TO_F32, Arity::Exact(1), false).work(1),
    graph(names::TO_I64, Arity::Exact(1), false).work(1),
    // ---- graph: unary float (all round through f32) -------------------
    graph(names::LOG, Arity::Exact(1), true),
    graph(names::LOG1P, Arity::Exact(1), true),
    graph(names::EXP, Arity::Exact(1), true),
    graph(names::SQRT, Arity::Exact(1), true),
    graph(names::ABS, Arity::Exact(1), true),
    graph(names::NEG, Arity::Exact(1), true),
    graph(names::RECIPROCAL, Arity::Exact(1), true),
    graph(names::ROUND, Arity::Exact(1), true),
    graph(names::FLOOR, Arity::Exact(1), true),
    graph(names::CEIL, Arity::Exact(1), true),
    graph(names::SIN, Arity::Exact(1), true),
    graph(names::COS, Arity::Exact(1), true),
    graph(names::TANH, Arity::Exact(1), true),
    graph(names::SIGMOID, Arity::Exact(1), true),
    graph(names::CLIP, Arity::Exact(1), true),
    graph(names::POW_SCALAR, Arity::Exact(1), true),
    graph_affine(names::ADD_SCALAR),
    graph_affine(names::SUB_SCALAR),
    graph_affine(names::MUL_SCALAR),
    graph_affine(names::DIV_SCALAR),
    graph_affine(names::SCALE_SHIFT),
    graph(names::AFFINE, Arity::Exact(1), true),
    // ---- graph: binary float ------------------------------------------
    graph(names::ADD, Arity::Exact(2), true),
    graph(names::SUB, Arity::Exact(2), true),
    graph(names::MUL, Arity::Exact(2), true),
    graph(names::DIV, Arity::Exact(2), true),
    graph(names::POW, Arity::Exact(2), true),
    graph(names::MIN, Arity::Exact(2), true),
    graph(names::MAX, Arity::Exact(2), true),
    graph(names::MOD, Arity::Exact(2), true),
    // ---- graph: the rest ----------------------------------------------
    // splits-table search: work is table-size-dependent, see node_cost
    graph(names::BUCKETIZE, Arity::Exact(1), false),
    graph(names::MULTI_BUCKETIZE, Arity::Exact(1), false).multi(),
    graph(names::COLUMNS_AGG, Arity::AtLeast(1), false).work(3),
    graph(names::DATE_PART, Arity::Exact(1), false).work(6),
    graph(names::SUB_I64, Arity::Exact(2), false),
    graph(names::ADD_SCALAR_I64, Arity::Exact(1), false),
    graph(names::FLOORDIV_SCALAR_I64, Arity::Exact(1), false),
    graph(names::COMPARE, Arity::Exact(2), false),
    graph(names::COMPARE_SCALAR, Arity::Exact(1), false),
    graph(names::EQ_HASH, Arity::Exact(1), false),
    graph(names::BOOL_OP, Arity::Exact(2), false),
    graph(names::NOT, Arity::Exact(1), false),
    graph(names::SELECT, Arity::Exact(3), false).work(3),
    graph(names::SELECT_CMP, Arity::Exact(3), false).work(4),
    graph(names::IS_NAN, Arity::Exact(1), false),
    graph(names::ASSEMBLE, Arity::AtLeast(1), false).work(3),
    graph(names::VECTOR_AT, Arity::Exact(1), false).work(1),
    graph(names::LIST_SUM, Arity::Exact(1), false).work(3),
    graph(names::LIST_MEAN, Arity::Exact(1), false).work(3),
    graph(names::LIST_MIN, Arity::Exact(1), false).work(3),
    graph(names::LIST_MAX, Arity::Exact(1), false).work(3),
    graph(names::LIST_LEN, Arity::Exact(1), false).work(1),
    graph(names::HASH_BUCKET, Arity::Exact(1), false).work(4),
    graph(names::BLOOM_ENCODE, Arity::Exact(1), false).work(8),
    graph(names::VOCAB_LOOKUP, Arity::Exact(1), false).work(6),
    graph(names::ONE_HOT, Arity::Exact(1), true).work(10),
    graph(names::SCALE_VEC, Arity::Exact(1), true).work(3),
    graph(names::IMPUTE, Arity::Exact(1), true),
    graph(names::COSINE_SIMILARITY, Arity::Exact(2), true).work(8),
    graph(names::HAVERSINE, Arity::Exact(4), true).work(12),
    // ---- both sections ------------------------------------------------
    both(names::ELEMENT_AT),
    both(names::SLICE_LIST),
];

/// Look up an op by name.
pub fn lookup(name: &str) -> Option<&'static OpInfo> {
    OPS.iter().find(|o| o.name == name)
}

// ---------------------------------------------------------------------------
// cost model

/// Fixed per-node overhead in the same units as [`OpInfo::work`]: one
/// output-column materialisation plus one env round trip per node in the
/// interpreter (one extra HLO op in the compiled graph). Fusion passes
/// win by collapsing k nodes' overheads into one.
pub const NODE_OVERHEAD: u64 = 8;

/// ~floor(log2(n)) + 1 — comparisons in a binary search over n entries.
fn search_depth(n: u64) -> u64 {
    (64 - n.leading_zeros()) as u64
}

/// Estimated per-row cost of one spec node: [`NODE_OVERHEAD`] plus op
/// work, attr-aware for fused ops (charged per recorded step, which is
/// exactly what makes fusion profitable under the model: the steps keep
/// their work, the interior overheads disappear) and for splits-table
/// searches (work grows with table depth). Unknown ops get a
/// conservative default. The coarse fast/slow split of the same numbers
/// is [`OpInfo::kernel_class`] — the kernel-program view of this model.
pub fn node_cost(node: &SpecNode) -> u64 {
    let base = lookup(&node.op).map(|i| i.work as u64).unwrap_or(4);
    let work = match node.op.as_str() {
        names::AFFINE => steps_work(&node.attrs, Some(2)),
        names::FUSED_INGRESS => steps_work(&node.attrs, None),
        names::BUCKETIZE | names::MULTI_BUCKETIZE => {
            // one binary search over the (possibly merged) splits table,
            // plus a unit of per-lane work for multi-output nodes (remap
            // gather / threshold compare per lane). Single-output nodes
            // keep the PR 2 estimate exactly (lanes is empty).
            let n = node.attrs.req_array("splits").map(|s| s.len()).unwrap_or(0) as u64;
            base + search_depth(n + 1) + node.lanes.len() as u64
        }
        _ => base,
    };
    NODE_OVERHEAD + work
}

/// Summed work of a fused node's recorded steps; `flat` charges a flat
/// per-step cost (affine steps are all scalar math), `None` charges each
/// step its registry work.
fn steps_work(attrs: &Json, flat: Option<u64>) -> u64 {
    match attrs.req_array("steps") {
        Ok(steps) => steps
            .iter()
            .map(|s| match flat {
                Some(w) => w,
                None => s
                    .opt_str("op")
                    .and_then(lookup)
                    .map(|i| i.work as u64)
                    .unwrap_or(4),
            })
            .sum::<u64>()
            .max(1),
        Err(_) => 4,
    }
}

/// Estimated per-row cost of a whole spec (ingress + graph sections) —
/// the objective the PassManager's fixpoint driver minimises.
pub fn spec_cost(spec: &GraphSpec) -> u64 {
    spec.ingress.iter().chain(spec.nodes.iter()).map(node_cost).sum()
}

/// Estimated per-row cost of serving ONE output subset of a spec: the
/// summed [`node_cost`] of the subset's ancestor cone
/// ([`GraphSpec::ancestor_cone`]). This is what a variant-routed
/// request actually pays on a merged multi-variant backend — the
/// serving-side counterpart of [`spec_cost`].
pub fn cone_cost(spec: &GraphSpec, outputs: &[&str]) -> u64 {
    let cone = spec.ancestor_cone(outputs);
    spec.ingress
        .iter()
        .zip(cone.ingress.iter())
        .chain(spec.nodes.iter().zip(cone.nodes.iter()))
        .filter(|(_, needed)| **needed)
        .map(|(n, _)| node_cost(n))
        .sum()
}

/// Per-variant cost attribution over a merged multi-variant spec.
#[derive(Debug, Clone)]
pub struct VariantCost {
    pub variant: String,
    /// Number of the variant's outputs.
    pub outputs: usize,
    /// Cost of nodes ONLY this variant's cone needs — what request
    /// routing stops charging to the other variants' rows.
    pub exclusive: u64,
    /// The variant's even share of nodes several variants' cones need
    /// (the deduped shared prefix).
    pub shared: u64,
}

/// Attribute a merged multi-variant spec's estimated cost to its
/// variants ([`GraphSpec::variants`]): each node's cost goes to the one
/// variant whose cone needs it, or is split evenly across the sharers.
/// Empty for ordinary single-variant specs. The sum of all
/// `exclusive + shared` equals the cost of the union cone (integer
/// division remainders are charged to the first sharer so nothing is
/// lost).
pub fn variant_costs(spec: &GraphSpec) -> Vec<VariantCost> {
    let variants = spec.variants();
    if variants.is_empty() {
        return Vec::new();
    }
    let cones: Vec<_> = variants
        .iter()
        .map(|v| spec.ancestor_cone_of(&spec.variant_outputs(v)))
        .collect();
    let mut out: Vec<VariantCost> = variants
        .iter()
        .map(|v| VariantCost {
            variant: v.to_string(),
            outputs: spec.variant_outputs(v).len(),
            exclusive: 0,
            shared: 0,
        })
        .collect();
    let mut charge = |node: &SpecNode, pick: &dyn Fn(&crate::export::Cone) -> bool| {
        let users: Vec<usize> = (0..cones.len()).filter(|&i| pick(&cones[i])).collect();
        if users.is_empty() {
            return;
        }
        let cost = node_cost(node);
        if users.len() == 1 {
            out[users[0]].exclusive += cost;
        } else {
            let share = cost / users.len() as u64;
            let remainder = cost - share * users.len() as u64;
            for (k, &u) in users.iter().enumerate() {
                out[u].shared += share + if k == 0 { remainder } else { 0 };
            }
        }
    };
    for (i, node) in spec.ingress.iter().enumerate() {
        charge(node, &|c| c.ingress[i]);
    }
    for (i, node) in spec.nodes.iter().enumerate() {
        charge(node, &|c| c.nodes[i]);
    }
    out
}

/// Look up an op, erroring with context on unknown names.
pub fn require(name: &str) -> Result<&'static OpInfo> {
    lookup(name).ok_or_else(|| KamaeError::Unsupported(format!("op not in registry: {name}")))
}

/// Structural lint of a spec against the registry: unknown ops, ops in
/// the wrong section, arity mismatches. Returns human-readable findings
/// (empty = clean). Unknown ops are reported, not fatal — the optimizer
/// treats them conservatively (impure, never folded).
pub fn lint_spec(spec: &GraphSpec) -> Vec<String> {
    let mut findings = Vec::new();
    for node in &spec.ingress {
        if !node.lanes.is_empty() {
            findings.push(format!(
                "ingress node {}: output lanes are graph-section only",
                node.id
            ));
        }
        match lookup(&node.op) {
            None => findings.push(format!("ingress node {}: unknown op '{}'", node.id, node.op)),
            Some(info) => {
                if !info.section.allows_ingress() {
                    findings.push(format!(
                        "ingress node {}: op '{}' is graph-only",
                        node.id, node.op
                    ));
                }
                if !info.arity.accepts(node.inputs.len()) {
                    findings.push(format!(
                        "ingress node {}: op '{}' got {} inputs",
                        node.id,
                        node.op,
                        node.inputs.len()
                    ));
                }
            }
        }
    }
    // lane names live in the node/column namespace: collect every
    // graph-side definition and flag collisions
    let mut defined: std::collections::HashSet<&str> =
        spec.graph_inputs.iter().map(String::as_str).collect();
    for node in &spec.nodes {
        for name in std::iter::once(node.id.as_str())
            .chain(node.lanes.iter().map(|l| l.name.as_str()))
        {
            if !defined.insert(name) {
                findings.push(format!(
                    "graph node {}: name '{name}' is defined more than once",
                    node.id
                ));
            }
        }
        match lookup(&node.op) {
            None => findings.push(format!("graph node {}: unknown op '{}'", node.id, node.op)),
            Some(info) => {
                if !info.section.allows_graph() {
                    findings.push(format!(
                        "graph node {}: op '{}' is ingress-only",
                        node.id, node.op
                    ));
                }
                if !info.arity.accepts(node.inputs.len()) {
                    findings.push(format!(
                        "graph node {}: op '{}' got {} inputs",
                        node.id,
                        node.op,
                        node.inputs.len()
                    ));
                }
                if !node.lanes.is_empty() && !info.multi_output {
                    findings.push(format!(
                        "graph node {}: op '{}' may not declare output lanes",
                        node.id, node.op
                    ));
                }
            }
        }
    }
    findings
}

/// Per-op execution templates: for every registered op, one concrete
/// (inputs, attrs, output dtype/width) instantiation plus the sample
/// DataFrame it runs against. Shared by the registry coverage tests
/// below and the kernel-program differential property
/// (`rust/tests/properties.rs`), which replays every template through
/// both the compiled kernel program and the `eval_node` oracle and pins
/// the outputs bit-for-bit. Hidden from docs: this is test scaffolding,
/// not API.
#[doc(hidden)]
pub mod coverage {
    use crate::dataframe::{Column, DType, DataFrame};
    use crate::export::{SpecDType, SpecInput};

    /// Sample batch covering every input shape the templates need:
    /// strings, string lists, f64/i64 scalars, fixed-width numeric
    /// lists, date and timestamp strings.
    pub fn sample_df() -> DataFrame {
        DataFrame::new(vec![
            ("s".into(), Column::from_str(vec!["alpha", "beta-1"])),
            ("ls".into(), Column::from_str_rows(vec![vec!["a", "b"], vec!["c", "d"]])),
            ("xf".into(), Column::from_f64(vec![1.5, -2.25])),
            ("yf".into(), Column::from_f64(vec![0.5, 3.0])),
            ("xi".into(), Column::from_i64(vec![3, 19_876])),
            ("vf".into(), Column::from_f64_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]])),
            ("vi".into(), Column::from_i64_rows(vec![vec![1, 2], vec![3, 4]])),
            ("d".into(), Column::from_str(vec!["2024-01-02", "1999-12-31"])),
            ("ts".into(), Column::from_str(vec!["2024-01-02 03:04:05", "1999-12-31 23:59:59"])),
        ])
        .unwrap()
    }

    /// Spec inputs matching [`sample_df`]'s numeric/graph columns.
    pub fn sample_inputs() -> Vec<SpecInput> {
        vec![
            SpecInput { name: "s".into(), dtype: DType::Str, width: None },
            SpecInput { name: "ls".into(), dtype: DType::List(Box::new(DType::Str)), width: Some(2) },
            SpecInput { name: "xf".into(), dtype: DType::F64, width: None },
            SpecInput { name: "yf".into(), dtype: DType::F64, width: None },
            SpecInput { name: "xi".into(), dtype: DType::I64, width: None },
            SpecInput { name: "vf".into(), dtype: DType::List(Box::new(DType::F64)), width: Some(2) },
            SpecInput { name: "vi".into(), dtype: DType::List(Box::new(DType::I64)), width: Some(2) },
        ]
    }

    /// (inputs, attrs-json, out dtype, out width) template for executing
    /// one graph-section op against [`sample_df`]. Adding an op to the
    /// registry without a template here fails the coverage test — by
    /// design: the interpreter (and model.py) must learn it too.
    pub fn graph_template(op: &str) -> (Vec<&'static str>, &'static str, SpecDType, Option<usize>) {
        use SpecDType::{F32, I64};
        match op {
            "identity" | "to_f32" => (vec!["xf"], "{}", F32, None),
            "to_i64" => (vec!["xf"], "{}", I64, None),
            "log" => (vec!["xf"], r#"{"base": 10.0}"#, F32, None),
            "log1p" | "exp" | "sqrt" | "abs" | "neg" | "reciprocal" | "round" | "floor"
            | "ceil" | "sin" | "cos" | "tanh" | "sigmoid" => (vec!["xf"], "{}", F32, None),
            "clip" => (vec!["xf"], r#"{"min": -1.0, "max": 1.0}"#, F32, None),
            "pow_scalar" => (vec!["xf"], r#"{"p": 2.0}"#, F32, None),
            "add_scalar" | "sub_scalar" | "mul_scalar" | "div_scalar" => {
                (vec!["xf"], r#"{"c": 2.5}"#, F32, None)
            }
            "scale_shift" => (vec!["xf"], r#"{"scale": 2.0, "shift": 1.0}"#, F32, None),
            "affine" => (
                vec!["xf"],
                r#"{"steps": [{"op": "mul_scalar", "c": 2.0}, {"op": "add_scalar", "c": 1.0}], "scale": 2.0, "shift": 1.0}"#,
                F32,
                None,
            ),
            "add" | "sub" | "mul" | "div" | "pow" | "min" | "max" | "mod" => {
                (vec!["xf", "yf"], "{}", F32, None)
            }
            "bucketize" => (vec!["xf"], r#"{"splits": [0.0, 1.0]}"#, I64, None),
            "multi_bucketize" => {
                (vec!["xf"], r#"{"splits": [0.0, 1.0], "op": "ge", "value": 1.0}"#, I64, None)
            }
            "columns_agg" => (vec!["xf", "yf"], r#"{"agg": "mean"}"#, F32, None),
            "date_part" => (vec!["xi"], r#"{"part": "weekday"}"#, I64, None),
            "sub_i64" => (vec!["xi", "xi"], "{}", I64, None),
            "add_scalar_i64" | "floordiv_scalar_i64" => (vec!["xi"], r#"{"c": 7}"#, I64, None),
            "compare" => (vec!["xf", "yf"], r#"{"op": "lt"}"#, I64, None),
            "compare_scalar" => (vec!["xf"], r#"{"op": "ge", "value": 0.0}"#, I64, None),
            "eq_hash" => (vec!["xi"], r#"{"value_hash": 3}"#, I64, None),
            "bool_op" => (vec!["xi", "xi"], r#"{"op": "and"}"#, I64, None),
            "not" | "is_nan" => (vec!["xi"], "{}", I64, None),
            "select" => (vec!["xi", "xf", "yf"], "{}", F32, None),
            "select_cmp" => (vec!["xf", "xf", "yf"], r#"{"op": "ge", "value": 0.0}"#, F32, None),
            "assemble" => (vec!["xf", "yf"], "{}", F32, Some(2)),
            "vector_at" => (vec!["vf"], r#"{"index": 1}"#, F32, None),
            "list_sum" | "list_mean" | "list_min" | "list_max" => (vec!["vf"], "{}", F32, None),
            "list_len" => (vec!["vf"], "{}", I64, None),
            "element_at" => (vec!["vf"], r#"{"index": -1}"#, F32, None),
            "slice_list" => (vec!["vf"], r#"{"start": 0, "len": 1}"#, F32, Some(1)),
            "hash_bucket" => (vec!["xi"], r#"{"num_bins": 16}"#, I64, None),
            "bloom_encode" => (vec!["xi"], r#"{"num_hashes": 2, "num_bins": 32}"#, I64, Some(2)),
            "vocab_lookup" => (
                vec!["xi"],
                r#"{"vocab_hashes": [3], "vocab_ranks": [0], "num_oov": 1, "base": 0}"#,
                I64,
                None,
            ),
            "one_hot" => (
                vec!["xi"],
                r#"{"vocab_hashes": [3], "vocab_ranks": [0], "num_oov": 1}"#,
                F32,
                Some(2),
            ),
            "scale_vec" => (vec!["vf"], r#"{"scale": [1.0, 2.0], "shift": [0.0, 1.0]}"#, F32, Some(2)),
            "impute" => (vec!["xf"], r#"{"fill": 0.0}"#, F32, None),
            "cosine_similarity" => (vec!["vf", "vf"], "{}", F32, None),
            "haversine" => (vec!["xf", "yf", "xf", "yf"], "{}", F32, None),
            other => panic!("graph op '{other}' has no interpreter-coverage template"),
        }
    }

    /// (input, attrs-json, out engine dtype, out width) template for one
    /// ingress op.
    pub fn ingress_template(op: &str) -> (&'static str, &'static str, DType, Option<usize>) {
        match op {
            "hash64" => ("s", "{}", DType::I64, None),
            "case" => ("s", r#"{"mode": "upper"}"#, DType::Str, None),
            "trim" | "to_string" => ("s", "{}", DType::Str, None),
            "substring" => ("s", r#"{"start": 0, "len": 2}"#, DType::Str, None),
            "replace" => ("s", r#"{"from": "a", "to": "b"}"#, DType::Str, None),
            "regex_replace" => ("s", r#"{"pattern": "[0-9]+", "rep": "#"}"#, DType::Str, None),
            "regex_extract" => ("s", r#"{"pattern": "([a-z]+)", "group": 1}"#, DType::Str, None),
            "concat" => ("s", r#"{"separator": "-"}"#, DType::Str, None),
            "split_pad" => (
                "s",
                r#"{"separator": "-", "list_length": 2, "default": "PAD"}"#,
                DType::List(Box::new(DType::Str)),
                Some(2),
            ),
            "join" => ("ls", r#"{"separator": ","}"#, DType::Str, None),
            "string_match" => ("s", r#"{"mode": "contains", "needle": "a"}"#, DType::Bool, None),
            "str_len" => ("s", "{}", DType::I64, None),
            "date_to_days" => ("d", "{}", DType::I64, None),
            "timestamp_to_seconds" => ("ts", "{}", DType::I64, None),
            "element_at" => ("ls", r#"{"index": 0}"#, DType::Str, None),
            "slice_list" => ("ls", r#"{"start": 0, "len": 1}"#, DType::List(Box::new(DType::Str)), Some(1)),
            "pad_list" => ("ls", r#"{"len": 3, "default": "PAD"}"#, DType::List(Box::new(DType::Str)), Some(3)),
            "parse_number" => ("d", "{}", DType::F64, None),
            "fused_ingress" => (
                "s",
                r#"{"steps": [{"op": "trim"}, {"op": "case", "mode": "upper"}, {"op": "hash64"}]}"#,
                DType::I64,
                None,
            ),
            other => panic!("ingress op '{other}' has no interpreter-coverage template"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::coverage::{graph_template, ingress_template, sample_df, sample_inputs};
    use super::*;
    use crate::dataframe::DType;
    use crate::engine::Dataset;
    use crate::export::{SpecDType, SpecInput, SpecInterpreter, SpecNode};
    use crate::pipeline::catalog;
    use crate::util::json::Json;

    #[test]
    fn lookup_known_and_unknown() {
        assert!(lookup(names::HASH_BUCKET).is_some());
        assert!(lookup(names::AFFINE).is_some());
        assert!(lookup("definitely_not_an_op").is_none());
        assert!(require("nope").is_err());
        // no duplicate names in the table
        for (i, a) in OPS.iter().enumerate() {
            for b in &OPS[i + 1..] {
                assert_ne!(a.name, b.name, "duplicate registry entry");
            }
        }
    }

    #[test]
    fn spec_name_helpers_are_registered() {
        use crate::ops::array::ListAgg;
        use crate::ops::math::{BinOp, UnaryOp};
        let unary = [
            UnaryOp::Log { base: None },
            UnaryOp::Log1p,
            UnaryOp::Exp,
            UnaryOp::Sqrt,
            UnaryOp::Abs,
            UnaryOp::Neg,
            UnaryOp::Reciprocal,
            UnaryOp::Round,
            UnaryOp::Floor,
            UnaryOp::Ceil,
            UnaryOp::Sin,
            UnaryOp::Cos,
            UnaryOp::Tanh,
            UnaryOp::Sigmoid,
            UnaryOp::Clip { min: None, max: None },
            UnaryOp::PowScalar { p: 2.0 },
            UnaryOp::AddScalar { c: 1.0 },
            UnaryOp::SubScalar { c: 1.0 },
            UnaryOp::MulScalar { c: 1.0 },
            UnaryOp::DivScalar { c: 1.0 },
            UnaryOp::ScaleShift { scale: 1.0, shift: 0.0 },
        ];
        for op in unary {
            let info = require(op.spec_name()).unwrap();
            assert!(info.section.allows_graph(), "{}", op.spec_name());
        }
        for op in [
            BinOp::Add,
            BinOp::Sub,
            BinOp::Mul,
            BinOp::Div,
            BinOp::Pow,
            BinOp::Min,
            BinOp::Max,
            BinOp::Mod,
        ] {
            assert!(require(op.spec_name()).is_ok(), "{}", op.spec_name());
        }
        for agg in [ListAgg::Sum, ListAgg::Mean, ListAgg::Min, ListAgg::Max, ListAgg::Len] {
            assert!(require(agg.spec_name()).is_ok(), "{}", agg.spec_name());
        }
    }

    #[test]
    fn lane_cost_and_lint() {
        use crate::export::SpecLane;
        let mut node = SpecNode {
            id: "x__lanes".into(),
            op: names::MULTI_BUCKETIZE.into(),
            inputs: vec!["x".into()],
            attrs: Json::parse(r#"{"splits": [0.0, 1.0]}"#).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let bare = node_cost(&node);
        let lane = |name: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(r#"{"kind": "bucket", "remap": [0, 1, 2]}"#).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        node.lanes = vec![lane("a"), lane("b")];
        // each lane charges a unit of work on top of the shared search
        assert_eq!(node_cost(&node), bare + 2);

        let spec = |nodes: Vec<SpecNode>| GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }],
            ingress: vec![],
            graph_inputs: vec!["x".into()],
            nodes,
            outputs: vec![],
        };
        // lanes on a multi_output op: clean
        assert!(lint_spec(&spec(vec![node.clone()])).is_empty());
        // lanes on an op that may not declare them: flagged
        let mut bad = node.clone();
        bad.op = names::BUCKETIZE.into();
        let findings = lint_spec(&spec(vec![bad]));
        assert!(findings.iter().any(|f| f.contains("may not declare output lanes")), "{findings:?}");
        // a lane name colliding with another definition: flagged
        let mut dup = node.clone();
        dup.lanes[1].name = "x".into(); // collides with the graph input
        let findings = lint_spec(&spec(vec![dup]));
        assert!(findings.iter().any(|f| f.contains("defined more than once")), "{findings:?}");
    }

    #[test]
    fn variant_cost_attribution_splits_shared_and_exclusive() {
        // merged two-variant shape: a shared ingress hash + shared
        // bucket node, plus one exclusive node per variant
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let spec = GraphSpec {
            name: "a+b".into(),
            inputs: vec![
                SpecInput { name: "c".into(), dtype: DType::Str, width: None },
                SpecInput { name: "x".into(), dtype: DType::F64, width: None },
            ],
            ingress: vec![node("a::c_h", names::HASH64, &["c"], "{}")],
            graph_inputs: vec!["a::c_h".into(), "x".into()],
            nodes: vec![
                node("a::idx", names::HASH_BUCKET, &["a::c_h"], r#"{"num_bins": 8}"#),
                node("a::flag", names::COMPARE_SCALAR, &["x"], r#"{"op": "ge", "value": 0.0}"#),
                node("b::idx", names::IDENTITY, &["a::idx"], "{}"),
                node("b::neg", names::NOT, &["a::flag"], "{}"),
            ],
            outputs: vec!["a::idx".into(), "a::flag".into(), "b::idx".into(), "b::neg".into()],
        };
        let costs = variant_costs(&spec);
        assert_eq!(costs.len(), 2);
        assert_eq!(costs[0].variant, "a");
        assert_eq!(costs[1].variant, "b");
        assert_eq!((costs[0].outputs, costs[1].outputs), (2, 2));
        // shared: the ingress hash, a::idx, a::flag (b's cone reaches
        // them through its identity/not consumers); exclusive: nothing
        // for a, b::idx + b::neg for b
        let shared_total = node_cost(&spec.ingress[0])
            + node_cost(&spec.nodes[0])
            + node_cost(&spec.nodes[1]);
        assert_eq!(costs[0].exclusive, 0);
        assert_eq!(
            costs[1].exclusive,
            node_cost(&spec.nodes[2]) + node_cost(&spec.nodes[3])
        );
        assert_eq!(costs[0].shared + costs[1].shared, shared_total);
        // nothing lost to rounding: attribution sums to the union cone
        let union: u64 = costs.iter().map(|c| c.exclusive + c.shared).sum();
        assert_eq!(union, spec_cost(&spec));
        // cone_cost agrees with a variant's own reachable set
        assert_eq!(
            cone_cost(&spec, &["a::idx", "a::flag"]),
            shared_total
        );
        // single-variant specs attribute nothing
        let plain = GraphSpec {
            name: "p".into(),
            inputs: vec![],
            ingress: vec![],
            graph_inputs: vec![],
            nodes: vec![],
            outputs: vec!["y".into()],
        };
        assert!(variant_costs(&plain).is_empty());
    }

    /// Every op a catalog pipeline can emit is known to the registry and
    /// sits in the section the builder placed it in.
    #[test]
    fn catalog_specs_only_emit_registered_ops() {
        let specs = [
            {
                let df = crate::synth::gen_movielens(&crate::synth::MovieLensConfig {
                    rows: 800,
                    ..Default::default()
                });
                catalog::movielens_pipeline()
                    .fit(&Dataset::from_dataframe(df, 2))
                    .unwrap()
                    .to_graph_spec_opt(
                        "m",
                        catalog::movielens_inputs(),
                        &catalog::MOVIELENS_OUTPUTS,
                        crate::optim::OptimizeLevel::None,
                    )
                    .unwrap()
                    .0
            },
            {
                let df = crate::synth::gen_ltr(&crate::synth::LtrConfig {
                    rows: 800,
                    ..Default::default()
                });
                catalog::ltr_pipeline()
                    .fit(&Dataset::from_dataframe(df, 2))
                    .unwrap()
                    .to_graph_spec_opt(
                        "l",
                        catalog::ltr_inputs(),
                        &catalog::LTR_OUTPUTS,
                        crate::optim::OptimizeLevel::None,
                    )
                    .unwrap()
                    .0
            },
        ];
        for spec in &specs {
            let findings = lint_spec(spec);
            assert!(findings.is_empty(), "{}: {findings:?}", spec.name);
        }
    }

    // ---- every registered op is executable by the interpreter ---------
    // (templates live in super::coverage, shared with tests/properties.rs)

    #[test]
    fn every_registered_graph_op_runs_in_the_interpreter() {
        let df = sample_df();
        for info in OPS.iter().filter(|o| o.section.allows_graph()) {
            let (inputs, attrs, dtype, width) = graph_template(info.name);
            assert!(
                info.arity.accepts(inputs.len()),
                "{}: template arity disagrees with registry",
                info.name
            );
            let spec = GraphSpec {
                name: format!("op_{}", info.name),
                inputs: sample_inputs(),
                ingress: vec![],
                graph_inputs: inputs.iter().map(|s| s.to_string()).collect(),
                nodes: vec![SpecNode {
                    id: "out".into(),
                    op: info.name.into(),
                    inputs: inputs.iter().map(|s| s.to_string()).collect(),
                    attrs: Json::parse(attrs).unwrap(),
                    dtype,
                    width,
                    lanes: vec![],
                }],
                outputs: vec!["out".into()],
            };
            let got = SpecInterpreter::new(spec).run(&df);
            assert!(got.is_ok(), "graph op {} failed: {:?}", info.name, got.err());
            assert_eq!(got.unwrap().len(), 1, "{}", info.name);
        }
    }

    #[test]
    fn every_registered_ingress_op_runs_in_the_interpreter() {
        let df = sample_df();
        for info in OPS.iter().filter(|o| o.section.allows_ingress()) {
            let (input, attrs, out_dtype, width) = ingress_template(info.name);
            let spec = GraphSpec {
                name: format!("ing_{}", info.name),
                inputs: vec![
                    SpecInput { name: "s".into(), dtype: DType::Str, width: None },
                    SpecInput {
                        name: "ls".into(),
                        dtype: DType::List(Box::new(DType::Str)),
                        width: Some(2),
                    },
                    SpecInput { name: "d".into(), dtype: DType::Str, width: None },
                    SpecInput { name: "ts".into(), dtype: DType::Str, width: None },
                ],
                ingress: vec![SpecNode {
                    id: "out".into(),
                    op: info.name.into(),
                    inputs: vec![input.to_string()],
                    attrs: Json::parse(attrs).unwrap(),
                    dtype: SpecDType::for_engine(&out_dtype),
                    width,
                    lanes: vec![],
                }],
                graph_inputs: vec![],
                nodes: vec![],
                outputs: vec![],
            };
            let got = SpecInterpreter::new(spec).run(&df);
            assert!(got.is_ok(), "ingress op {} failed: {:?}", info.name, got.err());
        }
    }
}
