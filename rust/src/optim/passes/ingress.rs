//! Ingress chain fusion.
//!
//! String preprocessing exports as one ingress node per step —
//! `split_pad` → `hash64`, `trim` → `case` → `hash64`, … — and the
//! serving ingress pays one full DataFrame column materialisation (plus
//! a column-map insert) per node on every request. This pass collapses
//! a maximal chain of single-input, single-consumer ingress nodes into
//! ONE `fused_ingress` node whose `attrs.steps` records the original
//! op/attr sequence.
//!
//! `export::interp` executes the fused node as a single walk over the
//! input column for the common per-value string shapes (trim / case /
//! replace / substring, optionally ending in `hash64`) and otherwise
//! replays the steps with the exact column kernels the separate nodes
//! used — bit-identical either way, intermediates never touch the
//! DataFrame.
//!
//! Interior chain nodes must have exactly one consumer (counting other
//! ingress nodes *and* `graph_inputs` references) so removing them is
//! invisible; the fused node inherits the chain tail's id, dtype and
//! width, so graph-side references are untouched. Already-fused nodes
//! flatten into longer chains (their steps are spliced), which keeps
//! the pass convergent under the fixpoint driver.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::optim::{names, registry, Pass};
use crate::util::json::Json;

pub struct IngressFuse;

/// A node that can participate in a fused chain: single-input, pure,
/// and known to the registry as an ingress-capable op.
fn fusable(node: &SpecNode) -> bool {
    node.inputs.len() == 1
        && registry::lookup(&node.op)
            .map(|info| info.pure && info.section.allows_ingress())
            .unwrap_or(false)
}

/// The step list a node contributes (flattens already-fused nodes).
fn steps_of(node: &SpecNode) -> Result<Vec<Json>> {
    if node.op == names::FUSED_INGRESS {
        Ok(node.attrs.req_array("steps")?.clone())
    } else {
        let mut step = node.attrs.clone();
        step.set("op", node.op.clone());
        Ok(vec![step])
    }
}

impl Pass for IngressFuse {
    fn name(&self) -> &'static str {
        "ingress-fuse"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        // how often each ingress-produced name is referenced: other
        // ingress nodes' inputs plus graph_inputs (the graph section
        // resolves ingress products only through graph_inputs)
        let mut uses: HashMap<String, usize> = HashMap::new();
        for n in &spec.ingress {
            for i in &n.inputs {
                *uses.entry(i.clone()).or_insert(0) += 1;
            }
        }
        for g in &spec.graph_inputs {
            *uses.entry(g.clone()).or_insert(0) += 1;
        }

        // the (unique) fusable ingress consumer of each ingress node
        let index: HashMap<&str, usize> =
            spec.ingress.iter().enumerate().map(|(i, n)| (n.id.as_str(), i)).collect();
        let mut consumer: HashMap<usize, usize> = HashMap::new();
        for (ci, node) in spec.ingress.iter().enumerate() {
            if fusable(node) {
                if let Some(&pi) = index.get(node.inputs[0].as_str()) {
                    consumer.insert(pi, ci);
                }
            }
        }

        let mut visited = vec![false; spec.ingress.len()];
        let mut removed = vec![false; spec.ingress.len()];
        let mut fused: Vec<(usize, SpecNode)> = Vec::new();

        // ingress nodes are topologically ordered, so chain heads are
        // reached before their interiors and each chain is found once
        for start in 0..spec.ingress.len() {
            if visited[start] || !fusable(&spec.ingress[start]) {
                continue;
            }
            // mark nodes visited AS the chain grows: a malformed cyclic
            // spec (lint warns but does not reject) must terminate the
            // walk, not hang the optimizer
            let mut chain = vec![start];
            visited[start] = true;
            let mut tail = start;
            loop {
                let tail_node = &spec.ingress[tail];
                if uses.get(&tail_node.id).copied().unwrap_or(0) != 1 {
                    break;
                }
                match consumer.get(&tail) {
                    Some(&next) if !visited[next] => {
                        visited[next] = true;
                        chain.push(next);
                        tail = next;
                    }
                    _ => break,
                }
            }
            if chain.len() < 2 {
                continue;
            }

            let mut steps: Vec<Json> = Vec::new();
            for &i in &chain {
                steps.extend(steps_of(&spec.ingress[i])?);
            }
            let mut attrs = Json::object();
            attrs.set("steps", Json::Array(steps));
            let head = &spec.ingress[chain[0]];
            let tail_node = &spec.ingress[*chain.last().unwrap()];
            fused.push((
                *chain.last().unwrap(),
                SpecNode {
                    id: tail_node.id.clone(),
                    op: names::FUSED_INGRESS.to_string(),
                    inputs: head.inputs.clone(),
                    attrs,
                    dtype: tail_node.dtype,
                    width: tail_node.width,
                    lanes: vec![],
                },
            ));
            for &i in &chain[..chain.len() - 1] {
                removed[i] = true;
            }
        }

        if fused.is_empty() {
            return Ok(false);
        }
        for (i, node) in fused {
            spec.ingress[i] = node;
        }
        let mut keep = removed.iter().map(|r| !r);
        spec.ingress.retain(|_| keep.next().unwrap());
        Ok(true)
    }
}
