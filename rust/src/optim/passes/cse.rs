//! Common-subexpression elimination.
//!
//! Two graph nodes with the same (op, inputs, attrs, dtype, width)
//! compute the same value — every registered graph op is deterministic
//! — so later duplicates are redirected to the first occurrence.
//! Large pipelines produce these naturally: repeated `log1p` feature
//! chains, the same hash feeding several encoders, copy-pasted stage
//! configs.
//!
//! Only ops marked `pure` in the registry participate; unknown ops are
//! skipped. A duplicate whose id is a spec output keeps its name (the
//! output contract) but is rewritten to an `identity` of the first
//! occurrence, so the value is still computed once.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::GraphSpec;
use crate::optim::{names, registry, Pass};
use crate::util::json::Json;

use super::{apply_renames, output_set};

pub struct CommonSubexprElim;

impl Pass for CommonSubexprElim {
    fn name(&self) -> &'static str {
        "cse"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let outputs = output_set(spec);
        let mut seen: HashMap<String, String> = HashMap::new();
        let mut renames: HashMap<String, String> = HashMap::new();
        let nodes = std::mem::take(&mut spec.nodes);
        let mut kept = Vec::with_capacity(nodes.len());
        let mut changed = false;

        for mut node in nodes {
            apply_renames(&mut node.inputs, &renames);
            let pure = registry::lookup(&node.op).map(|i| i.pure).unwrap_or(false);
            // multi-output nodes need per-lane redirection — that is
            // CrossOutputDedup's job, not this pass's
            if !pure || !node.lanes.is_empty() {
                kept.push(node);
                continue;
            }
            // the shared structural identity (same key CrossOutputDedup
            // hashes by — the two passes must never disagree)
            let key = super::structural_key(&node);
            match seen.get(&key) {
                Some(first) if first != &node.id => {
                    changed = true;
                    if outputs.contains(&node.id) {
                        // keep the output name alive as a cheap alias
                        node.op = names::IDENTITY.to_string();
                        node.inputs = vec![first.clone()];
                        node.attrs = Json::object();
                        kept.push(node);
                    } else {
                        renames.insert(node.id, first.clone());
                    }
                }
                _ => {
                    seen.insert(key, node.id.clone());
                    kept.push(node);
                }
            }
        }

        spec.nodes = kept;
        Ok(changed)
    }
}
