//! The optimizer pass suite.
//!
//! Every pass upholds the subsystem's exactness contract (see
//! [`crate::optim`] module docs): interpreter outputs are preserved
//! bit-for-bit, spec output names are never renamed, and unknown ops
//! are treated conservatively (impure, never folded or fused).

mod affine;
mod bucketize;
mod cse;
mod dce;
mod dedup;
mod fold;
mod identity;
mod ingress;
mod multilane;
mod select;

pub use affine::AffineFuse;
pub use bucketize::BucketizeMerge;
pub use cse::CommonSubexprElim;
pub use dce::DeadNodeElim;
pub use dedup::CrossOutputDedup;
pub use fold::ConstFold;
pub use identity::IdentityElim;
pub use ingress::IngressFuse;
pub use multilane::MultiLaneBucketize;
pub use select::SelectCmpFuse;

use std::collections::{HashMap, HashSet};

use crate::export::{GraphSpec, SpecDType};

/// Dtype/width of every graph-section name (graph inputs resolved
/// through ingress, every node output, and every lane of a multi-output
/// node — under both its qualified `"id.lane"` reference and its bare
/// name).
pub(crate) fn meta_map(spec: &GraphSpec) -> HashMap<String, (SpecDType, Option<usize>)> {
    let mut m = HashMap::new();
    for g in &spec.graph_inputs {
        if let Some(meta) = spec.graph_input_meta(g) {
            m.insert(g.clone(), meta);
        }
    }
    for n in &spec.nodes {
        if n.lanes.is_empty() {
            m.insert(n.id.clone(), (n.dtype, n.width));
        }
        // a multi-output node's bare id is not a value — only its lanes
        // (qualified and bare) resolve
        for l in &n.lanes {
            m.insert(n.lane_ref(&l.name), (l.dtype, l.width));
            m.insert(l.name.clone(), (l.dtype, l.width));
        }
    }
    m
}

/// How many times each graph-section name is referenced (node inputs
/// plus spec outputs).
pub(crate) fn use_counts(spec: &GraphSpec) -> HashMap<String, usize> {
    let mut uses: HashMap<String, usize> = HashMap::new();
    for n in &spec.nodes {
        for i in &n.inputs {
            *uses.entry(i.clone()).or_insert(0) += 1;
        }
    }
    for o in &spec.outputs {
        *uses.entry(o.clone()).or_insert(0) += 1;
    }
    uses
}

/// The set of spec output names (never renamed by any pass).
pub(crate) fn output_set(spec: &GraphSpec) -> HashSet<String> {
    spec.outputs.iter().cloned().collect()
}

/// Rewrite a node input through an accumulated rename map. Map values
/// are already fully resolved at insertion time, so one hop suffices.
pub(crate) fn apply_renames(inputs: &mut [String], renames: &HashMap<String, String>) {
    for i in inputs.iter_mut() {
        if let Some(t) = renames.get(i) {
            *i = t.clone();
        }
    }
}

/// Structural identity of a node — the ONE key both dedup-style passes
/// (CSE, CrossOutputDedup) hash by, so they can never disagree about
/// which nodes are "the same computation". `\x1f`/`\x1e` cannot appear
/// in column names coming from JSON specs. Lane *names* are
/// deliberately not part of the key (lane identity is positional);
/// everything else about the lanes is.
pub(crate) fn structural_key(node: &crate::export::SpecNode) -> String {
    let mut key = format!(
        "{}\x1f{}\x1f{}\x1f{}\x1f{:?}",
        node.op,
        node.inputs.join("\x1f"),
        node.attrs,
        node.dtype.name(),
        node.width
    );
    for l in &node.lanes {
        key.push_str(&format!("\x1e{}\x1f{}\x1f{:?}", l.attrs, l.dtype.name(), l.width));
    }
    key
}

#[cfg(test)]
mod tests {
    use crate::dataframe::DType;
    use crate::export::{GraphSpec, SpecDType, SpecInput, SpecNode};
    use crate::optim::{names, optimize, OptimizeLevel, Pass};
    use crate::util::json::Json;

    use super::*;

    fn node(
        id: &str,
        op: &str,
        inputs: &[&str],
        attrs: &str,
        dtype: SpecDType,
        width: Option<usize>,
    ) -> SpecNode {
        SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width,
            lanes: vec![],
        }
    }

    /// Spec over a raw float `x` and a string `c` (hashed at ingress).
    fn base_spec(nodes: Vec<SpecNode>, outputs: &[&str]) -> GraphSpec {
        GraphSpec {
            name: "t".into(),
            inputs: vec![
                SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                SpecInput { name: "c".into(), dtype: DType::Str, width: None },
            ],
            ingress: vec![node("c__hash", names::HASH64, &["c"], "{}", SpecDType::I64, None)],
            graph_inputs: vec!["x".into(), "c__hash".into()],
            nodes,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }

    #[test]
    fn dce_drops_dead_nodes_inputs_and_ingress() {
        let mut spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("dead", names::EXP, &["x"], "{}", SpecDType::F32, None),
                node("idx", names::HASH_BUCKET, &["c__hash"], r#"{"num_bins": 8}"#, SpecDType::I64, None),
            ],
            &["l"],
        );
        assert!(DeadNodeElim.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 1);
        assert_eq!(spec.nodes[0].id, "l");
        // the hash feature died, so its graph input and ingress node go too
        assert_eq!(spec.graph_inputs, vec!["x".to_string()]);
        assert!(spec.ingress.is_empty());
        // second run: fixpoint
        assert!(!DeadNodeElim.run(&mut spec).unwrap());
    }

    #[test]
    fn identity_elim_rewires_consumers_but_keeps_outputs() {
        let mut spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("i", names::IDENTITY, &["l"], "{}", SpecDType::F32, None),
                node("e", names::EXP, &["i"], "{}", SpecDType::F32, None),
                node("o", names::IDENTITY, &["l"], "{}", SpecDType::F32, None),
            ],
            &["e", "o"],
        );
        assert!(IdentityElim.run(&mut spec).unwrap());
        let ids: Vec<&str> = spec.nodes.iter().map(|n| n.id.as_str()).collect();
        assert_eq!(ids, vec!["l", "e", "o"]); // "i" gone, output alias "o" kept
        assert_eq!(spec.nodes[1].inputs, vec!["l".to_string()]);
    }

    #[test]
    fn identity_elim_removes_noop_casts_only() {
        let mut spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                // no-op: float -> to_f32
                node("lf", names::TO_F32, &["l"], "{}", SpecDType::F32, None),
                node("e", names::EXP, &["lf"], "{}", SpecDType::F32, None),
                // real cast: float -> to_i64 must survive
                node("li", names::TO_I64, &["l"], "{}", SpecDType::I64, None),
                node("n", names::NOT, &["li"], "{}", SpecDType::I64, None),
            ],
            &["e", "n"],
        );
        assert!(IdentityElim.run(&mut spec).unwrap());
        let ids: Vec<&str> = spec.nodes.iter().map(|n| n.id.as_str()).collect();
        assert_eq!(ids, vec!["l", "e", "li", "n"]);
        assert_eq!(spec.nodes[1].inputs, vec!["l".to_string()]);
    }

    #[test]
    fn const_fold_requires_a_rounded_producer() {
        let mut spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                // producer rounds through f32: foldable
                node("a", names::MUL_SCALAR, &["l"], r#"{"c": 1.0}"#, SpecDType::F32, None),
                // producer is the raw request input: NOT foldable (the
                // multiply's f32 rounding is observable downstream)
                node("b", names::MUL_SCALAR, &["x"], r#"{"c": 1.0}"#, SpecDType::F32, None),
            ],
            &["a", "b"],
        );
        assert!(ConstFold.run(&mut spec).unwrap());
        assert_eq!(spec.nodes[1].op, names::IDENTITY);
        assert_eq!(spec.nodes[2].op, names::MUL_SCALAR);
    }

    #[test]
    fn cse_dedupes_and_aliases_output_duplicates() {
        let mut spec = base_spec(
            vec![
                node("l1", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("l2", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("e1", names::EXP, &["l1"], "{}", SpecDType::F32, None),
                node("e2", names::EXP, &["l2"], "{}", SpecDType::F32, None),
            ],
            &["e1", "e2"],
        );
        assert!(CommonSubexprElim.run(&mut spec).unwrap());
        let ids: Vec<&str> = spec.nodes.iter().map(|n| n.id.as_str()).collect();
        assert_eq!(ids, vec!["l1", "e1", "e2"]); // l2 merged into l1
        // e2 became a rename-aware duplicate of e1; being an output it
        // survives as an identity alias
        assert_eq!(spec.nodes[2].op, names::IDENTITY);
        assert_eq!(spec.nodes[2].inputs, vec!["e1".to_string()]);
    }

    #[test]
    fn affine_fuse_collapses_single_use_chains() {
        let mut spec = base_spec(
            vec![
                node("t1", names::ADD_SCALAR, &["x"], r#"{"c": 1.0}"#, SpecDType::F32, None),
                node("t2", names::MUL_SCALAR, &["t1"], r#"{"c": 2.0}"#, SpecDType::F32, None),
            ],
            &["t2"],
        );
        assert!(AffineFuse.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 1);
        let fused = &spec.nodes[0];
        assert_eq!(fused.id, "t2");
        assert_eq!(fused.op, names::AFFINE);
        assert_eq!(fused.inputs, vec!["x".to_string()]);
        let steps = fused.attrs.req_array("steps").unwrap();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].req_str("op").unwrap(), names::ADD_SCALAR);
        // collapsed (x+1)*2 = 2x + 2
        assert_eq!(fused.attrs.req_f64("scale").unwrap(), 2.0);
        assert_eq!(fused.attrs.req_f64("shift").unwrap(), 2.0);
    }

    #[test]
    fn affine_fuse_stops_at_multi_use_and_output_boundaries() {
        let mut spec = base_spec(
            vec![
                node("t1", names::ADD_SCALAR, &["x"], r#"{"c": 1.0}"#, SpecDType::F32, None),
                node("t2", names::MUL_SCALAR, &["t1"], r#"{"c": 2.0}"#, SpecDType::F32, None),
                // second consumer of t1 pins it
                node("e", names::EXP, &["t1"], "{}", SpecDType::F32, None),
            ],
            &["t2", "e"],
        );
        assert!(!AffineFuse.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 3);
    }

    #[test]
    fn ingress_fuse_collapses_chains_and_flattens() {
        // trim -> case -> hash64 over c, hash feeding the graph
        let mut spec = GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "c".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("c_t", names::TRIM, &["c"], "{}", SpecDType::I64, None),
                node("c_u", names::CASE, &["c_t"], r#"{"mode": "upper"}"#, SpecDType::I64, None),
                node("c_h", names::HASH64, &["c_u"], "{}", SpecDType::I64, None),
            ],
            graph_inputs: vec!["c_h".into()],
            nodes: vec![node(
                "idx",
                names::HASH_BUCKET,
                &["c_h"],
                r#"{"num_bins": 8}"#,
                SpecDType::I64,
                None,
            )],
            outputs: vec!["idx".into()],
        };
        assert!(IngressFuse.run(&mut spec).unwrap());
        assert_eq!(spec.ingress.len(), 1);
        let fused = &spec.ingress[0];
        assert_eq!(fused.op, names::FUSED_INGRESS);
        assert_eq!(fused.id, "c_h"); // tail id: graph refs untouched
        assert_eq!(fused.inputs, vec!["c".to_string()]);
        let steps = fused.attrs.req_array("steps").unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(steps[0].req_str("op").unwrap(), names::TRIM);
        assert_eq!(steps[2].req_str("op").unwrap(), names::HASH64);
        // second run: nothing left to fuse
        assert!(!IngressFuse.run(&mut spec).unwrap());
    }

    #[test]
    fn ingress_fuse_respects_multi_use_interiors() {
        // c_t feeds both case and the graph section: not fusable past it
        let mut spec = GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "c".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("c_t", names::TRIM, &["c"], "{}", SpecDType::I64, None),
                node("c_u", names::CASE, &["c_t"], r#"{"mode": "upper"}"#, SpecDType::I64, None),
                node("c_th", names::HASH64, &["c_t"], "{}", SpecDType::I64, None),
                node("c_uh", names::HASH64, &["c_u"], "{}", SpecDType::I64, None),
            ],
            graph_inputs: vec!["c_th".into(), "c_uh".into()],
            nodes: vec![],
            outputs: vec![],
        };
        // c_t has two consumers (case + hash64), so only case->hash64 fuses
        assert!(IngressFuse.run(&mut spec).unwrap());
        assert_eq!(spec.ingress.len(), 3);
        assert!(spec.ingress.iter().any(|n| n.id == "c_t" && n.op == names::TRIM));
        assert!(spec.ingress.iter().any(|n| n.id == "c_uh" && n.op == names::FUSED_INGRESS));
    }

    #[test]
    fn ingress_fuse_terminates_on_cyclic_specs() {
        // a malformed spec with mutually-referential ingress nodes gets
        // through lint_spec (warnings only); the chain walk must
        // terminate rather than hang the optimizer / server startup
        let mut spec = GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "c".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("a", names::TRIM, &["b"], "{}", SpecDType::I64, None),
                node("b", names::TRIM, &["a"], "{}", SpecDType::I64, None),
            ],
            graph_inputs: vec![],
            nodes: vec![],
            outputs: vec![],
        };
        let _ = IngressFuse.run(&mut spec).unwrap();
    }

    #[test]
    fn bucketize_merge_fuses_dead_index_ladders() {
        let mut spec = base_spec(
            vec![
                node("b", names::BUCKETIZE, &["x"], r#"{"splits": [0.0, 1.0, 2.0]}"#, SpecDType::I64, None),
                node("flag", names::COMPARE_SCALAR, &["b"], r#"{"op": "le", "value": 1.0}"#, SpecDType::I64, None),
            ],
            &["flag"],
        );
        assert!(BucketizeMerge.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 1);
        let fused = &spec.nodes[0];
        assert_eq!(fused.op, names::MULTI_BUCKETIZE);
        assert_eq!(fused.id, "flag");
        assert_eq!(fused.inputs, vec!["x".to_string()]);
        assert_eq!(fused.attrs.req_array("splits").unwrap().len(), 3);
        assert_eq!(fused.attrs.req_str("op").unwrap(), "le");
        assert!(!BucketizeMerge.run(&mut spec).unwrap());
    }

    #[test]
    fn bucketize_merge_keeps_visible_indices() {
        // the bucket index is itself an output: fusing would duplicate it
        let mut spec = base_spec(
            vec![
                node("b", names::BUCKETIZE, &["x"], r#"{"splits": [0.0]}"#, SpecDType::I64, None),
                node("flag", names::COMPARE_SCALAR, &["b"], r#"{"op": "ge", "value": 1.0}"#, SpecDType::I64, None),
            ],
            &["b", "flag"],
        );
        assert!(!BucketizeMerge.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 2);
    }

    #[test]
    fn select_cmp_fuse_removes_dead_masks() {
        let mut spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("m", names::COMPARE_SCALAR, &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64, None),
                node("s", names::SELECT, &["m", "l", "x"], "{}", SpecDType::F32, None),
            ],
            &["s"],
        );
        assert!(SelectCmpFuse.run(&mut spec).unwrap());
        let ids: Vec<&str> = spec.nodes.iter().map(|n| n.id.as_str()).collect();
        assert_eq!(ids, vec!["l", "s"]); // mask gone
        let fused = &spec.nodes[1];
        assert_eq!(fused.op, names::SELECT_CMP);
        assert_eq!(fused.inputs, vec!["x".to_string(), "l".to_string(), "x".to_string()]);
        assert_eq!(fused.attrs.req_str("op").unwrap(), "gt");
        assert!(!SelectCmpFuse.run(&mut spec).unwrap());
    }

    #[test]
    fn select_cmp_fuse_leaves_output_masks() {
        let mut spec = base_spec(
            vec![
                node("m", names::COMPARE_SCALAR, &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64, None),
                node("s", names::SELECT, &["m", "x", "x"], "{}", SpecDType::F32, None),
            ],
            &["m", "s"],
        );
        assert!(!SelectCmpFuse.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 2);
    }

    #[test]
    fn multilane_bucketize_merges_siblings() {
        let mut spec = base_spec(
            vec![
                node("b1", names::BUCKETIZE, &["x"], r#"{"splits": [0.0, 1.0]}"#, SpecDType::I64, None),
                node("b2", names::BUCKETIZE, &["x"], r#"{"splits": [0.5]}"#, SpecDType::I64, None),
                node("c1", names::COMPARE_SCALAR, &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64, None),
                node("n", names::NOT, &["b2"], "{}", SpecDType::I64, None),
            ],
            &["b1", "c1", "n"],
        );
        assert!(MultiLaneBucketize.run(&mut spec).unwrap());
        // one merged multi-output node + the rewired consumer
        assert_eq!(spec.nodes.len(), 2);
        let m = &spec.nodes[0];
        assert_eq!(m.op, names::MULTI_BUCKETIZE);
        assert_eq!(m.id, "x__lanes");
        assert_eq!(m.inputs, vec!["x".to_string()]);
        let splits: Vec<f64> = m
            .attrs
            .req_array("splits")
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(splits, vec![0.0, 0.5, 1.0]);
        let lane_names: Vec<&str> = m.lanes.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(lane_names, vec!["b1", "b2", "c1"]);
        // remap recovers each sibling's own bucket index from the merged one
        let remap = |i: usize| -> Vec<i64> {
            m.lanes[i]
                .attrs
                .req_array("remap")
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap())
                .collect()
        };
        assert_eq!(remap(0), vec![0, 1, 1, 2]);
        assert_eq!(remap(1), vec![0, 0, 1, 1]);
        assert_eq!(m.lanes[2].attrs.req_str("kind").unwrap(), "compare");
        // the surviving consumer was rewired to the qualified lane ref
        assert_eq!(spec.nodes[1].inputs, vec!["x__lanes.b2".to_string()]);
        // fixpoint: the merged node is not itself a merge candidate
        assert!(!MultiLaneBucketize.run(&mut spec).unwrap());
    }

    #[test]
    fn multilane_bucketize_absorbs_fused_ladders() {
        // a PR-2 single-output multi_bucketize ladder joins the group as
        // a bucket_compare lane
        let mut spec = base_spec(
            vec![
                node("b1", names::BUCKETIZE, &["x"], r#"{"splits": [0.0]}"#, SpecDType::I64, None),
                node(
                    "flag",
                    names::MULTI_BUCKETIZE,
                    &["x"],
                    r#"{"splits": [-1.0, 1.0], "op": "ge", "value": 2.0}"#,
                    SpecDType::I64,
                    None,
                ),
            ],
            &["b1", "flag"],
        );
        assert!(MultiLaneBucketize.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 1);
        let m = &spec.nodes[0];
        assert_eq!(m.lanes[1].attrs.req_str("kind").unwrap(), "bucket_compare");
        assert_eq!(m.lanes[1].attrs.req_str("op").unwrap(), "ge");
    }

    #[test]
    fn multilane_bucketize_needs_a_shared_search() {
        // two bare compares share no splits search: left alone
        let mut spec = base_spec(
            vec![
                node("c1", names::COMPARE_SCALAR, &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64, None),
                node("c2", names::COMPARE_SCALAR, &["x"], r#"{"op": "lt", "value": 1.0}"#, SpecDType::I64, None),
            ],
            &["c1", "c2"],
        );
        assert!(!MultiLaneBucketize.run(&mut spec).unwrap());
        // a single bucketize has no sibling: left alone
        let mut spec = base_spec(
            vec![node("b", names::BUCKETIZE, &["x"], r#"{"splits": [0.0]}"#, SpecDType::I64, None)],
            &["b"],
        );
        assert!(!MultiLaneBucketize.run(&mut spec).unwrap());
        // unsorted splits disqualify the node (partition_point semantics
        // over an unsorted table cannot be reproduced from a merged one)
        let mut spec = base_spec(
            vec![
                node("b1", names::BUCKETIZE, &["x"], r#"{"splits": [1.0, 0.0]}"#, SpecDType::I64, None),
                node("b2", names::BUCKETIZE, &["x"], r#"{"splits": [0.5]}"#, SpecDType::I64, None),
            ],
            &["b1", "b2"],
        );
        assert!(!MultiLaneBucketize.run(&mut spec).unwrap());
    }

    #[test]
    fn cross_output_dedup_collapses_variant_copies() {
        // the shape GraphSpec::merge_variants produces: two variants,
        // identical ingress chain and graph chain, different prefixes
        let mut spec = GraphSpec {
            name: "m".into(),
            inputs: vec![SpecInput { name: "c".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("a::c_h", names::HASH64, &["c"], "{}", SpecDType::I64, None),
                node("b::c_h", names::HASH64, &["c"], "{}", SpecDType::I64, None),
            ],
            graph_inputs: vec!["a::c_h".into(), "b::c_h".into()],
            nodes: vec![
                node("a::idx", names::HASH_BUCKET, &["a::c_h"], r#"{"num_bins": 8}"#, SpecDType::I64, None),
                node("b::idx", names::HASH_BUCKET, &["b::c_h"], r#"{"num_bins": 8}"#, SpecDType::I64, None),
            ],
            outputs: vec!["a::idx".into(), "b::idx".into()],
        };
        assert!(CrossOutputDedup.run(&mut spec).unwrap());
        // ingress shared, graph input deduped
        assert_eq!(spec.ingress.len(), 1);
        assert_eq!(spec.graph_inputs, vec!["a::c_h".to_string()]);
        // the second variant's chain keyed identically after the ingress
        // rename cascaded, so it collapsed to an output alias
        assert_eq!(spec.nodes.len(), 2);
        assert_eq!(spec.nodes[0].id, "a::idx");
        assert_eq!(spec.nodes[0].op, names::HASH_BUCKET);
        assert_eq!(spec.nodes[0].inputs, vec!["a::c_h".to_string()]);
        assert_eq!(spec.nodes[1].id, "b::idx");
        assert_eq!(spec.nodes[1].op, names::IDENTITY);
        assert_eq!(spec.nodes[1].inputs, vec!["a::idx".to_string()]);
        // second run: fixpoint
        assert!(!CrossOutputDedup.run(&mut spec).unwrap());
    }

    #[test]
    fn cross_output_dedup_redirects_lanes_positionally() {
        use crate::export::SpecLane;
        let lane = |name: &str, attrs: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        let mlb = |id: &str, lanes: Vec<SpecLane>| {
            let mut n = node(
                id,
                names::MULTI_BUCKETIZE,
                &["x"],
                r#"{"splits": [0.0, 1.0]}"#,
                SpecDType::I64,
                None,
            );
            n.lanes = lanes;
            n
        };
        let mut spec = base_spec(
            vec![
                mlb(
                    "a::x__lanes",
                    vec![
                        lane("a::bucket", r#"{"kind": "bucket", "remap": [0, 1, 2]}"#),
                        lane("a::flag", r#"{"kind": "compare", "op": "gt", "value": 0.0}"#),
                    ],
                ),
                mlb(
                    "b::x__lanes",
                    vec![
                        lane("b::bucket", r#"{"kind": "bucket", "remap": [0, 1, 2]}"#),
                        lane("b::flag", r#"{"kind": "compare", "op": "gt", "value": 0.0}"#),
                    ],
                ),
                node("b::n", names::NOT, &["b::x__lanes.b::flag"], "{}", SpecDType::I64, None),
            ],
            &["a::bucket", "b::bucket", "b::n"],
        );
        assert!(CrossOutputDedup.run(&mut spec).unwrap());
        // the duplicate multi-output node is gone; its output-named lane
        // survives as an identity alias of the kept node's lane, and the
        // consumer's qualified ref was redirected positionally
        let ids: Vec<&str> = spec.nodes.iter().map(|n| n.id.as_str()).collect();
        assert_eq!(ids, vec!["a::x__lanes", "b::bucket", "b::n"]);
        assert_eq!(spec.nodes[1].op, names::IDENTITY);
        assert_eq!(spec.nodes[1].inputs, vec!["a::x__lanes.a::bucket".to_string()]);
        assert_eq!(spec.nodes[2].inputs, vec!["a::x__lanes.a::flag".to_string()]);
    }

    #[test]
    fn dce_prunes_dead_lanes_and_lane_only_live_nodes() {
        use crate::export::SpecLane;
        let lane = |name: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(r#"{"kind": "bucket", "remap": [0, 1]}"#).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        let mut mlb = node(
            "x__lanes",
            names::MULTI_BUCKETIZE,
            &["x"],
            r#"{"splits": [0.0]}"#,
            SpecDType::I64,
            None,
        );
        mlb.lanes = vec![lane("keep_out"), lane("keep_ref"), lane("dead")];
        let mut spec = base_spec(
            vec![
                mlb,
                node("n", names::NOT, &["x__lanes.keep_ref"], "{}", SpecDType::I64, None),
            ],
            // "keep_out" is live through its bare lane name (spec output)
            &["keep_out", "n"],
        );
        assert!(DeadNodeElim.run(&mut spec).unwrap());
        let lane_names: Vec<&str> =
            spec.nodes[0].lanes.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(lane_names, vec!["keep_out", "keep_ref"]);
        // nothing references any lane -> the whole node dies
        let mut mlb = node(
            "x__lanes",
            names::MULTI_BUCKETIZE,
            &["x"],
            r#"{"splits": [0.0]}"#,
            SpecDType::I64,
            None,
        );
        mlb.lanes = vec![lane("a"), lane("b")];
        let mut spec = base_spec(
            vec![mlb, node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None)],
            &["l"],
        );
        assert!(DeadNodeElim.run(&mut spec).unwrap());
        assert_eq!(spec.nodes.len(), 1);
        assert_eq!(spec.nodes[0].id, "l");
    }

    #[test]
    fn optimize_is_idempotent() {
        let spec = base_spec(
            vec![
                node("l", names::LOG1P, &["x"], "{}", SpecDType::F32, None),
                node("t1", names::ADD_SCALAR, &["l"], r#"{"c": 1.0}"#, SpecDType::F32, None),
                node("t2", names::MUL_SCALAR, &["t1"], r#"{"c": 2.0}"#, SpecDType::F32, None),
                node("dead", names::EXP, &["x"], "{}", SpecDType::F32, None),
                node("o", names::IDENTITY, &["t2"], "{}", SpecDType::F32, None),
            ],
            &["o"],
        );
        let (once, _) = optimize(spec, OptimizeLevel::Full).unwrap();
        let (twice, report) = optimize(once.clone(), OptimizeLevel::Full).unwrap();
        assert_eq!(once, twice, "second optimize run changed the spec:\n{report}");
        assert!(report.stats.iter().all(|s| !s.changed), "{report}");
    }
}
