//! Cross-output subgraph deduplication — one evaluation env, shared.
//!
//! [`CommonSubexprElim`](super::CommonSubexprElim) dedupes graph nodes;
//! this pass generalises the idea to the whole spec so that a
//! **multi-variant** spec ([`GraphSpec::merge_variants`]) serving K
//! output variants pays for the shared preprocessing prefix once
//! instead of K times:
//!
//! * **ingress nodes** dedupe too — variants share the string-side work
//!   (trims, splits, hashes, fused chains), and the graph-input list is
//!   rewritten/deduplicated to match,
//! * **multi-output nodes** dedupe by structure with lane names
//!   *excluded* from the key: two merged fan-outs computing identical
//!   lanes under different (variant-prefixed) names collapse to one,
//!   lane by lane, with every `"<id>.<lane>"` reference and bare lane
//!   name redirected to the kept node's corresponding lane,
//! * duplicates whose name is a spec output keep the name alive as an
//!   `identity` alias — spec outputs are never renamed.
//!
//! Renames accumulate front-to-back, so chains collapse transitively in
//! one sweep: once variant B's hash dedupes onto variant A's, B's
//! downstream nodes key identically to A's and dedupe in turn — the
//! whole overlapping subgraph folds. On a freshly CSE'd single-variant
//! spec the pass is a no-op.
//!
//! Exactness: only ops the registry marks pure participate, and a
//! duplicate is removed exactly when op, (renamed) inputs, attrs, dtype
//! and width all match — the evaluation it redirects to is the same
//! computation, bit for bit.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::optim::{names, registry, Pass};
use crate::util::json::Json;

use super::{apply_renames, output_set, structural_key};

pub struct CrossOutputDedup;

impl Pass for CrossOutputDedup {
    fn name(&self) -> &'static str {
        "cross-output-dedup"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let outputs = output_set(spec);
        let mut renames: HashMap<String, String> = HashMap::new();
        let mut changed = false;

        // ---- ingress section ---------------------------------------------
        let ingress = std::mem::take(&mut spec.ingress);
        let mut seen: HashMap<String, String> = HashMap::new();
        let mut kept = Vec::with_capacity(ingress.len());
        for mut node in ingress {
            apply_renames(&mut node.inputs, &renames);
            let pure = registry::lookup(&node.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                kept.push(node);
                continue;
            }
            let key = structural_key(&node);
            match seen.get(&key) {
                // an output-named duplicate keeps its name (output names
                // are sacred and ingress has no identity op to alias
                // with) — but it still *registers* below on first sight,
                // so later copies dedupe onto it
                Some(first) if first != &node.id && !outputs.contains(&node.id) => {
                    changed = true;
                    renames.insert(node.id, first.clone());
                }
                _ => {
                    if !seen.contains_key(&key) {
                        seen.insert(key, node.id.clone());
                    }
                    kept.push(node);
                }
            }
        }
        spec.ingress = kept;

        // graph inputs follow the ingress renames and dedupe in order
        let graph_inputs = std::mem::take(&mut spec.graph_inputs);
        for g in graph_inputs {
            let g = renames.get(&g).cloned().unwrap_or(g);
            if !spec.graph_inputs.contains(&g) {
                spec.graph_inputs.push(g);
            }
        }

        // ---- graph section ------------------------------------------------
        // key -> (kept node id, kept node's lane names in order)
        let mut seen_g: HashMap<String, (String, Vec<String>)> = HashMap::new();
        let nodes = std::mem::take(&mut spec.nodes);
        let mut kept = Vec::with_capacity(nodes.len());
        for mut node in nodes {
            apply_renames(&mut node.inputs, &renames);
            let pure = registry::lookup(&node.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                kept.push(node);
                continue;
            }
            let key = structural_key(&node);
            match seen_g.get(&key) {
                Some((first, first_lanes)) if first != &node.id => {
                    if node.lanes.is_empty() {
                        changed = true;
                        if outputs.contains(&node.id) {
                            // keep the output name alive as a cheap alias
                            node.op = names::IDENTITY.to_string();
                            node.inputs = vec![first.clone()];
                            node.attrs = Json::object();
                            kept.push(node);
                        } else {
                            renames.insert(node.id, first.clone());
                        }
                    } else if node
                        .lanes
                        .iter()
                        .any(|dl| outputs.contains(&node.lane_ref(&dl.name)))
                    {
                        // a *qualified* lane ref used directly as a spec
                        // output — never produced by our own exporter,
                        // but output names are sacred: leave the node
                        kept.push(node);
                    } else {
                        changed = true;
                        // redirect lane by lane (identity is positional)
                        for (dl, kl_name) in node.lanes.iter().zip(first_lanes) {
                            let target = format!("{first}.{kl_name}");
                            renames
                                .insert(format!("{}.{}", node.id, dl.name), target.clone());
                            if outputs.contains(&dl.name) {
                                kept.push(SpecNode {
                                    id: dl.name.clone(),
                                    op: names::IDENTITY.to_string(),
                                    inputs: vec![target],
                                    attrs: Json::object(),
                                    dtype: dl.dtype,
                                    width: dl.width,
                                    lanes: vec![],
                                });
                            } else {
                                renames.insert(dl.name.clone(), target);
                            }
                        }
                    }
                }
                _ => {
                    let lane_names = node.lanes.iter().map(|l| l.name.clone()).collect();
                    seen_g.insert(key, (node.id.clone(), lane_names));
                    kept.push(node);
                }
            }
        }
        spec.nodes = kept;
        Ok(changed)
    }
}
