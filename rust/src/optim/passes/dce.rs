//! Dead-node elimination.
//!
//! The builder exports every fitted stage, but serving only needs what
//! the declared outputs depend on — offline-only features (labels,
//! diagnostics, intermediate columns that never reach the model) ride
//! along as dead weight. This pass walks liveness backwards from
//! `spec.outputs` and drops:
//!
//! 1. graph nodes not reachable from any output,
//! 2. graph inputs no remaining node or output references,
//! 3. ingress nodes (and their upstream ingress chains) that only fed
//!    pruned graph inputs.
//!
//! Removing never-evaluated nodes cannot change surviving values, so
//! the pass is unconditionally exact. Nodes whose op is unknown to the
//! registry or not pure are pinned live (conservative: they might have
//! effects).
//!
//! Multi-output nodes are live when *any* of their lanes is referenced
//! — by qualified `"id.lane"` reference or by bare lane name (spec
//! outputs use the latter). On a surviving node, individually dead
//! lanes are pruned (a never-read lane is never-evaluated work), as
//! long as at least one lane remains.

use std::collections::HashSet;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::optim::{registry, Pass};

pub struct DeadNodeElim;

/// Whether any of the node's produced names is referenced.
fn node_is_live(n: &SpecNode, live: &HashSet<String>) -> bool {
    live.contains(&n.id)
        || n.lanes
            .iter()
            .any(|l| live.contains(&l.name) || live.contains(&n.lane_ref(&l.name)))
}

impl Pass for DeadNodeElim {
    fn name(&self) -> &'static str {
        "dead-node-elim"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let lanes_before: usize = spec.nodes.iter().map(|n| n.lanes.len()).sum();
        let before =
            (spec.nodes.len(), spec.graph_inputs.len(), spec.ingress.len(), lanes_before);

        // ---- graph section -------------------------------------------
        let mut live: HashSet<String> = spec.outputs.iter().cloned().collect();
        // pin impure/unknown ops
        for n in &spec.nodes {
            let pure = registry::lookup(&n.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                live.insert(n.id.clone());
            }
        }
        for n in spec.nodes.iter().rev() {
            if node_is_live(n, &live) {
                live.extend(n.inputs.iter().cloned());
            }
        }
        spec.nodes.retain(|n| node_is_live(n, &live));
        // prune individually dead lanes on surviving multi-output nodes
        // (keeping at least one — an empty lane list would change the
        // node's meaning)
        for n in &mut spec.nodes {
            if n.lanes.is_empty() {
                continue;
            }
            let lane_live: Vec<bool> = n
                .lanes
                .iter()
                .map(|l| live.contains(&l.name) || live.contains(&n.lane_ref(&l.name)))
                .collect();
            if lane_live.iter().any(|&b| b) && !lane_live.iter().all(|&b| b) {
                let mut keep = lane_live.into_iter();
                n.lanes.retain(|_| keep.next().unwrap());
            }
        }
        spec.graph_inputs.retain(|g| live.contains(g));

        // ---- ingress section -----------------------------------------
        let mut live_i: HashSet<String> = spec.graph_inputs.iter().cloned().collect();
        for n in &spec.ingress {
            let pure = registry::lookup(&n.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                live_i.insert(n.id.clone());
            }
        }
        for n in spec.ingress.iter().rev() {
            if live_i.contains(&n.id) {
                live_i.extend(n.inputs.iter().cloned());
            }
        }
        spec.ingress.retain(|n| live_i.contains(&n.id));

        let lanes_after: usize = spec.nodes.iter().map(|n| n.lanes.len()).sum();
        Ok(before
            != (spec.nodes.len(), spec.graph_inputs.len(), spec.ingress.len(), lanes_after))
    }
}
