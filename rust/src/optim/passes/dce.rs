//! Dead-node elimination.
//!
//! The builder exports every fitted stage, but serving only needs what
//! the declared outputs depend on — offline-only features (labels,
//! diagnostics, intermediate columns that never reach the model) ride
//! along as dead weight. This pass walks liveness backwards from
//! `spec.outputs` and drops:
//!
//! 1. graph nodes not reachable from any output,
//! 2. graph inputs no remaining node or output references,
//! 3. ingress nodes (and their upstream ingress chains) that only fed
//!    pruned graph inputs.
//!
//! Removing never-evaluated nodes cannot change surviving values, so
//! the pass is unconditionally exact. Nodes whose op is unknown to the
//! registry or not pure are pinned live (conservative: they might have
//! effects).

use std::collections::HashSet;

use crate::error::Result;
use crate::export::GraphSpec;
use crate::optim::{registry, Pass};

pub struct DeadNodeElim;

impl Pass for DeadNodeElim {
    fn name(&self) -> &'static str {
        "dead-node-elim"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let before = (spec.nodes.len(), spec.graph_inputs.len(), spec.ingress.len());

        // ---- graph section -------------------------------------------
        let mut live: HashSet<String> = spec.outputs.iter().cloned().collect();
        // pin impure/unknown ops
        for n in &spec.nodes {
            let pure = registry::lookup(&n.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                live.insert(n.id.clone());
            }
        }
        for n in spec.nodes.iter().rev() {
            if live.contains(&n.id) {
                live.extend(n.inputs.iter().cloned());
            }
        }
        spec.nodes.retain(|n| live.contains(&n.id));
        spec.graph_inputs.retain(|g| live.contains(g));

        // ---- ingress section -----------------------------------------
        let mut live_i: HashSet<String> = spec.graph_inputs.iter().cloned().collect();
        for n in &spec.ingress {
            let pure = registry::lookup(&n.op).map(|i| i.pure).unwrap_or(false);
            if !pure {
                live_i.insert(n.id.clone());
            }
        }
        for n in spec.ingress.iter().rev() {
            if live_i.contains(&n.id) {
                live_i.extend(n.inputs.iter().cloned());
            }
        }
        spec.ingress.retain(|n| live_i.contains(&n.id));

        Ok(before != (spec.nodes.len(), spec.graph_inputs.len(), spec.ingress.len()))
    }
}
