//! Bucketize/compare ladder merging.
//!
//! Quantile binning followed by threshold flags exports as a two-node
//! ladder — `bucketize(x, splits)` then `compare_scalar(bucket, op, k)`
//! — that materialises a full bucket-index column only to compare it
//! against a constant. When the bucket index is invisible outside the
//! compare (single consumer, not a spec output), this pass collapses
//! the ladder into ONE `multi_bucketize` node: one sorted-splits binary
//! search per value feeding the threshold compare directly.
//!
//! Exactness: the fused op replays both original steps verbatim — the
//! split search runs on raw f64 values exactly like `bucketize` (no
//! rounding), and the bucket index is compared with `compare_scalar`'s
//! f32 rounding discipline (a no-op for the small integers bucket
//! indices are, but replayed anyway). i64 outputs are bit-identical.
//!
//! The pass skips ladders whose attrs it cannot validate (malformed
//! splits, unknown cmp op) and list-typed inputs — conservatism over
//! cleverness.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::ops::logical::CmpOp;
use crate::optim::{names, Pass};

use super::{output_set, use_counts};

pub struct BucketizeMerge;

/// A bucketize node whose ladder may fuse: scalar, with a well-formed
/// f64 splits table.
fn mergeable_bucketize(node: &SpecNode) -> bool {
    node.op == names::BUCKETIZE
        && node.inputs.len() == 1
        && node.width.is_none()
        && node
            .attrs
            .req_array("splits")
            .map(|s| s.iter().all(|v| v.as_f64().is_some()))
            .unwrap_or(false)
}

/// A compare_scalar node with a parseable op and value.
fn mergeable_compare(node: &SpecNode) -> bool {
    node.op == names::COMPARE_SCALAR
        && node.inputs.len() == 1
        && node.width.is_none()
        && node
            .attrs
            .opt_str("op")
            .map(|o| CmpOp::from_name(o).is_ok())
            .unwrap_or(false)
        && node.attrs.opt_f64("value").is_some()
}

impl Pass for BucketizeMerge {
    fn name(&self) -> &'static str {
        "bucketize-merge"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let uses = use_counts(spec);
        let outputs = output_set(spec);
        let bucketize_at: HashMap<&str, usize> = spec
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| mergeable_bucketize(n))
            .map(|(i, n)| (n.id.as_str(), i))
            .collect();

        let mut removed = vec![false; spec.nodes.len()];
        let mut rewrites: Vec<(usize, SpecNode)> = Vec::new();
        for (ci, node) in spec.nodes.iter().enumerate() {
            if !mergeable_compare(node) {
                continue;
            }
            let Some(&bi) = bucketize_at.get(node.inputs[0].as_str()) else {
                continue;
            };
            let bucket = &spec.nodes[bi];
            // the bucket index must be invisible outside this compare
            if removed[bi]
                || outputs.contains(&bucket.id)
                || uses.get(&bucket.id).copied().unwrap_or(0) != 1
            {
                continue;
            }
            let mut attrs = bucket.attrs.clone(); // carries "splits"
            attrs.set("op", node.attrs.req_str("op")?.to_string());
            attrs.set("value", node.attrs.req_f64("value")?);
            rewrites.push((
                ci,
                SpecNode {
                    id: node.id.clone(),
                    op: names::MULTI_BUCKETIZE.to_string(),
                    inputs: bucket.inputs.clone(),
                    attrs,
                    dtype: node.dtype,
                    width: node.width,
                    lanes: vec![],
                },
            ));
            removed[bi] = true;
        }

        if rewrites.is_empty() {
            return Ok(false);
        }
        for (i, node) in rewrites {
            spec.nodes[i] = node;
        }
        let mut keep = removed.iter().map(|r| !r);
        spec.nodes.retain(|_| keep.next().unwrap());
        Ok(true)
    }
}
