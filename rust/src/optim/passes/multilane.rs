//! Multi-lane bucketize merging — sibling fan-outs over one input.
//!
//! Quantile features tend to fan out: the same raw column feeds a
//! coarse `bucketize`, a fine `bucketize`, and a couple of
//! `compare_scalar` threshold flags. Each sibling costs a full node —
//! column materialisation, env round trip, and (for the bucketizes) its
//! own binary search over its own splits table. This pass merges ≥ 2
//! sibling nodes over the *same scalar input* into ONE multi-output
//! `multi_bucketize` node ([`crate::export::SpecLane`]): a single
//! binary search over the merged (sorted, deduplicated) splits table
//! emits one lane per original sibling, and consumers are rewired to
//! `"<merged_id>.<lane>"` references. Lanes keep the merged-away node
//! ids as their names, so spec outputs — which are never renamed —
//! resolve through the lane's bare-name binding with no alias nodes.
//!
//! Mergeable siblings:
//!
//! * `bucketize(x, splits_i)` → a `"bucket"` lane. Its `remap` table
//!   recovers the original bucket index from the merged search:
//!   `remap[k] = |{s ∈ splits_i : s ≤ M[k-1]}|` (`remap[0] = 0`).
//!   Because `splits_i ⊆ M`, both sorted, and the search compares raw
//!   f64 exactly like `bucketize`, the lane is bit-exact.
//! * `compare_scalar(x, op, v)` → a `"compare"` lane replaying the
//!   compare's f32 operand rounding verbatim. It rides the merged
//!   node's single column walk (its rounding makes the raw-f64 search
//!   unusable for it — conservatism over cleverness).
//! * single-output `multi_bucketize` ladders (PR 2's bucketize→compare
//!   fusion) → a `"bucket_compare"` lane: remapped bucket index, then
//!   the f32-rounded threshold compare, step for step.
//!
//! Nodes with unsorted or non-finite splits tables, list-typed widths,
//! or unparseable attrs never join a group. Groups need at least one
//! splits-carrying member — merging two bare compares would share no
//! search, only overhead, and the cost-guarded PassManager would veto
//! marginal rewrites anyway.

use std::collections::{HashMap, HashSet};

use crate::error::Result;
use crate::export::{GraphSpec, SpecLane, SpecNode};
use crate::ops::logical::CmpOp;
use crate::optim::{names, Pass};
use crate::util::json::Json;

use super::apply_renames;

pub struct MultiLaneBucketize;

/// How one sibling node becomes a lane.
enum Member {
    /// `bucketize` with its (sorted, finite) splits table.
    Bucket(Vec<f64>),
    /// `compare_scalar` (op/value validated).
    Compare,
    /// single-output `multi_bucketize` ladder with its splits table.
    BucketCompare(Vec<f64>),
}

/// Parse a sorted all-finite f64 splits table; `None` disqualifies.
fn sorted_splits(attrs: &Json) -> Option<Vec<f64>> {
    let arr = attrs.req_array("splits").ok()?;
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let f = v.as_f64()?;
        if !f.is_finite() {
            return None;
        }
        out.push(f);
    }
    if out.windows(2).any(|w| w[0] > w[1]) {
        return None;
    }
    Some(out)
}

fn valid_compare_attrs(attrs: &Json) -> bool {
    attrs
        .opt_str("op")
        .map(|o| CmpOp::from_name(o).is_ok())
        .unwrap_or(false)
        && attrs.opt_f64("value").is_some()
}

/// Classify a node as a mergeable sibling.
fn member_of(node: &SpecNode) -> Option<Member> {
    if node.inputs.len() != 1 || node.width.is_some() || !node.lanes.is_empty() {
        return None;
    }
    match node.op.as_str() {
        names::BUCKETIZE => sorted_splits(&node.attrs).map(Member::Bucket),
        names::COMPARE_SCALAR if valid_compare_attrs(&node.attrs) => Some(Member::Compare),
        names::MULTI_BUCKETIZE if valid_compare_attrs(&node.attrs) => {
            sorted_splits(&node.attrs).map(Member::BucketCompare)
        }
        _ => None,
    }
}

/// `remap[k]` = original bucket index for merged index `k` — the number
/// of this member's splits ≤ the k-th merged prefix bound.
fn remap_table(member_splits: &[f64], merged: &[f64]) -> Vec<i64> {
    let mut remap = Vec::with_capacity(merged.len() + 1);
    remap.push(0);
    for bound in merged {
        remap.push(member_splits.partition_point(|&s| s <= *bound) as i64);
    }
    remap
}

impl Pass for MultiLaneBucketize {
    fn name(&self) -> &'static str {
        "multilane-bucketize"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        // group mergeable siblings by their input name, in node order
        let mut group_of: HashMap<String, usize> = HashMap::new();
        let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
        let mut members: Vec<Option<Member>> = Vec::with_capacity(spec.nodes.len());
        for (i, node) in spec.nodes.iter().enumerate() {
            let m = member_of(node);
            if m.is_some() {
                let input = node.inputs[0].clone();
                let gi = *group_of.entry(input.clone()).or_insert_with(|| {
                    groups.push((input, Vec::new()));
                    groups.len() - 1
                });
                groups[gi].1.push(i);
            }
            members.push(m);
        }

        // every name already defined in the graph section (for unique
        // merged-node ids)
        let mut taken: HashSet<String> = spec
            .graph_inputs
            .iter()
            .cloned()
            .chain(spec.nodes.iter().map(|n| n.id.clone()))
            .chain(
                spec.nodes
                    .iter()
                    .flat_map(|n| n.lanes.iter().map(|l| l.name.clone())),
            )
            .chain(spec.inputs.iter().map(|i| i.name.clone()))
            .collect();

        let mut merged_at: HashMap<usize, SpecNode> = HashMap::new();
        let mut removed = vec![false; spec.nodes.len()];
        let mut renames: HashMap<String, String> = HashMap::new();
        for (input, idxs) in &groups {
            if idxs.len() < 2 {
                continue;
            }
            // merged splits: sorted union of every carrier's table
            let mut merged: Vec<f64> = Vec::new();
            for &i in idxs {
                match members[i].as_ref().expect("grouped") {
                    Member::Bucket(s) | Member::BucketCompare(s) => merged.extend(s),
                    Member::Compare => {}
                }
            }
            if merged.is_empty() {
                // compares only: no search to share
                continue;
            }
            merged.sort_by(|a, b| a.partial_cmp(b).expect("finite splits"));
            merged.dedup();

            // '.' is the lane-reference separator — keep generated ids
            // clean of it even when the shared input is itself a lane
            let mut id = format!("{}__lanes", input.replace('.', "_"));
            while taken.contains(&id) {
                id.push('_');
            }
            taken.insert(id.clone());

            let mut lanes = Vec::with_capacity(idxs.len());
            for &i in idxs {
                let node = &spec.nodes[i];
                let mut attrs = Json::object();
                match members[i].as_ref().expect("grouped") {
                    Member::Bucket(s) => {
                        attrs.set("kind", "bucket");
                        attrs.set(
                            "remap",
                            Json::Array(
                                remap_table(s, &merged).into_iter().map(Json::Int).collect(),
                            ),
                        );
                    }
                    Member::Compare => {
                        attrs.set("kind", "compare");
                        attrs.set("op", node.attrs.req_str("op")?.to_string());
                        attrs.set("value", node.attrs.req_f64("value")?);
                    }
                    Member::BucketCompare(s) => {
                        attrs.set("kind", "bucket_compare");
                        attrs.set(
                            "remap",
                            Json::Array(
                                remap_table(s, &merged).into_iter().map(Json::Int).collect(),
                            ),
                        );
                        attrs.set("op", node.attrs.req_str("op")?.to_string());
                        attrs.set("value", node.attrs.req_f64("value")?);
                    }
                }
                lanes.push(SpecLane {
                    name: node.id.clone(),
                    attrs,
                    dtype: node.dtype,
                    width: node.width,
                });
                renames.insert(node.id.clone(), format!("{id}.{}", node.id));
                removed[i] = true;
            }

            let mut attrs = Json::object();
            attrs.set(
                "splits",
                Json::Array(merged.iter().map(|&s| Json::Float(s)).collect()),
            );
            merged_at.insert(
                idxs[0],
                SpecNode {
                    id,
                    op: names::MULTI_BUCKETIZE.to_string(),
                    inputs: vec![input.clone()],
                    attrs,
                    dtype: crate::export::SpecDType::I64,
                    width: None,
                    lanes,
                },
            );
        }

        if merged_at.is_empty() {
            return Ok(false);
        }
        let nodes = std::mem::take(&mut spec.nodes);
        let mut kept = Vec::with_capacity(nodes.len());
        for (i, mut node) in nodes.into_iter().enumerate() {
            if let Some(m) = merged_at.remove(&i) {
                kept.push(m);
            }
            if removed[i] {
                continue;
            }
            apply_renames(&mut node.inputs, &renames);
            kept.push(node);
        }
        spec.nodes = kept;
        Ok(true)
    }
}
