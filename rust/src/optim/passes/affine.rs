//! Scalar-affine chain fusion.
//!
//! Pipelines are full of scalar-math ladders — `(x - 1) * (2π/12)`,
//! unit conversions, normalisations — which the builder exports as one
//! node per step. Each interpreted step costs a full column
//! materialisation plus an env round trip; each compiled step is an
//! extra HLO op. This pass collapses a maximal chain of single-use
//! `add_scalar` / `sub_scalar` / `mul_scalar` / `div_scalar` /
//! `scale_shift` nodes into ONE fused `affine` node.
//!
//! The fused node's attrs carry two representations:
//!
//! * `steps` — the original op/constant sequence. The interpreter
//!   replays it step-by-step with the exact same f32 rounding the
//!   separate nodes had, which is what makes this pass bit-exact under
//!   `SpecInterpreter`.
//! * `scale` / `shift` — the composition collapsed to `x*scale + shift`
//!   (f64), for reporting and kernel lowering.
//!   `python/compile/model.py` lowers the canonical mul-then-add/sub
//!   pattern onto the fused-scaling Pallas kernel
//!   (`kernels.affine_scale`) — same semantics as `scale_vec`, within
//!   the kernel's f32 FMA contraction — and replays `steps` otherwise.
//!
//! Interior chain nodes must have exactly one consumer and must not be
//! spec outputs; the fused node inherits the chain tail's id, so
//! downstream references are untouched.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::optim::{names, registry, Pass};
use crate::util::json::Json;

use super::{output_set, use_counts};

pub struct AffineFuse;

/// One original chain step, as recorded in `attrs.steps`.
struct Step {
    op: String,
    attrs: Json,
}

/// Parse a node as an affine step; `None` if it is not fusable.
fn as_step(node: &SpecNode) -> Option<Step> {
    let info = registry::lookup(&node.op)?;
    if !info.affine || node.inputs.len() != 1 {
        return None;
    }
    // validate the constants now so fusion never produces a node the
    // interpreter cannot evaluate
    let ok = if node.op == names::SCALE_SHIFT {
        node.attrs.opt_f64("scale").is_some() && node.attrs.opt_f64("shift").is_some()
    } else {
        node.attrs.opt_f64("c").is_some()
    };
    if !ok {
        return None;
    }
    Some(Step { op: node.op.clone(), attrs: node.attrs.clone() })
}

/// Compose the collapsed `x*scale + shift` form of a step sequence.
fn collapse(steps: &[Step]) -> (f64, f64) {
    let (mut scale, mut shift) = (1.0f64, 0.0f64);
    for s in steps {
        match s.op.as_str() {
            names::ADD_SCALAR => shift += s.attrs.opt_f64("c").unwrap_or(0.0),
            names::SUB_SCALAR => shift -= s.attrs.opt_f64("c").unwrap_or(0.0),
            names::MUL_SCALAR => {
                let c = s.attrs.opt_f64("c").unwrap_or(1.0);
                scale *= c;
                shift *= c;
            }
            names::DIV_SCALAR => {
                let c = s.attrs.opt_f64("c").unwrap_or(1.0);
                scale /= c;
                shift /= c;
            }
            _ => {
                // scale_shift
                let s2 = s.attrs.opt_f64("scale").unwrap_or(1.0);
                let t2 = s.attrs.opt_f64("shift").unwrap_or(0.0);
                scale *= s2;
                shift = shift * s2 + t2;
            }
        }
    }
    (scale, shift)
}

impl Pass for AffineFuse {
    fn name(&self) -> &'static str {
        "affine-fuse"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let uses = use_counts(spec);
        let outputs = output_set(spec);
        // id -> node index, and the (unique) affine consumer of each id
        let index: HashMap<&str, usize> =
            spec.nodes.iter().enumerate().map(|(i, n)| (n.id.as_str(), i)).collect();
        let mut affine_consumer: HashMap<usize, usize> = HashMap::new();
        for (ci, node) in spec.nodes.iter().enumerate() {
            if as_step(node).is_some() {
                if let Some(&pi) = index.get(node.inputs[0].as_str()) {
                    affine_consumer.insert(pi, ci);
                }
            }
        }

        let mut visited = vec![false; spec.nodes.len()];
        let mut removed = vec![false; spec.nodes.len()];
        let mut fused: Vec<(usize, SpecNode)> = Vec::new();

        for start in 0..spec.nodes.len() {
            if visited[start] || as_step(&spec.nodes[start]).is_none() {
                continue;
            }
            // grow the chain forward while the current tail has exactly
            // one consumer, that consumer is the next affine step, and
            // the tail's value is not externally visible. Nodes are
            // marked visited as they are appended so a malformed cyclic
            // spec terminates the walk instead of hanging it.
            let mut chain = vec![start];
            visited[start] = true;
            let mut tail = start;
            loop {
                let tail_node = &spec.nodes[tail];
                let single_use = uses.get(&tail_node.id).copied().unwrap_or(0) == 1;
                if !single_use || outputs.contains(&tail_node.id) {
                    break;
                }
                match affine_consumer.get(&tail) {
                    Some(&next) if !visited[next] => {
                        visited[next] = true;
                        chain.push(next);
                        tail = next;
                    }
                    _ => break,
                }
            }
            if chain.len() < 2 {
                continue;
            }

            let steps: Vec<Step> =
                chain.iter().map(|&i| as_step(&spec.nodes[i]).expect("validated")).collect();
            let (scale, shift) = collapse(&steps);
            let mut attrs = Json::object();
            attrs.set(
                "steps",
                Json::Array(
                    steps
                        .iter()
                        .map(|s| {
                            let mut o = s.attrs.clone();
                            o.set("op", s.op.clone());
                            o
                        })
                        .collect(),
                ),
            );
            attrs.set("scale", scale);
            attrs.set("shift", shift);

            let head = &spec.nodes[chain[0]];
            let tail_node = &spec.nodes[*chain.last().unwrap()];
            fused.push((
                *chain.last().unwrap(),
                SpecNode {
                    id: tail_node.id.clone(),
                    op: names::AFFINE.to_string(),
                    inputs: vec![head.inputs[0].clone()],
                    attrs,
                    dtype: tail_node.dtype,
                    width: tail_node.width,
                    lanes: vec![],
                },
            ));
            for &i in &chain[..chain.len() - 1] {
                removed[i] = true;
            }
        }

        if fused.is_empty() {
            return Ok(false);
        }
        for (i, node) in fused {
            spec.nodes[i] = node;
        }
        let mut keep = removed.iter().map(|r| !r);
        spec.nodes.retain(|_| keep.next().unwrap());
        Ok(true)
    }
}
