//! Identity and no-op-cast elimination.
//!
//! Removes three shapes of pass-through node, rewiring consumers to the
//! node's input:
//!
//! * `identity` — the interpreter evaluates it as a value clone (no
//!   rounding), so removal is unconditionally exact,
//! * `to_f32` whose input is already `float32` — the interpreter's
//!   `as_f()` on a float value is a clone, and the compiled graph's
//!   `astype(float32)` on a float32 array is a no-op,
//! * `to_i64` whose input is already `int64` — same reasoning.
//!
//! A cast whose input has a *different* dtype class is a real
//! conversion and is kept. Nodes whose id is a spec output are kept
//! (output names are an external contract), as are nodes whose
//! declared dtype/width disagree with their input's (a malformed or
//! hand-edited spec — leave it alone).

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecDType};
use crate::optim::{names, Pass};

use super::{apply_renames, meta_map, output_set};

pub struct IdentityElim;

impl Pass for IdentityElim {
    fn name(&self) -> &'static str {
        "identity-elim"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let meta = meta_map(spec);
        let outputs = output_set(spec);
        let mut renames: HashMap<String, String> = HashMap::new();
        let nodes = std::mem::take(&mut spec.nodes);
        let mut kept = Vec::with_capacity(nodes.len());

        for mut node in nodes {
            apply_renames(&mut node.inputs, &renames);
            let removable = !outputs.contains(&node.id)
                && node.inputs.len() == 1
                && match meta.get(&node.inputs[0]) {
                    Some(&(in_dtype, in_width)) => {
                        in_width == node.width
                            && match node.op.as_str() {
                                names::IDENTITY => in_dtype == node.dtype,
                                names::TO_F32 => {
                                    in_dtype == SpecDType::F32 && node.dtype == SpecDType::F32
                                }
                                names::TO_I64 => {
                                    in_dtype == SpecDType::I64 && node.dtype == SpecDType::I64
                                }
                                _ => false,
                            }
                    }
                    None => false,
                };
            if removable {
                // inputs[0] is already fully resolved (renames applied
                // above), so map values never need a second hop.
                renames.insert(node.id, node.inputs[0].clone());
            } else {
                kept.push(node);
            }
        }

        let changed = !renames.is_empty();
        spec.nodes = kept;
        Ok(changed)
    }
}
