//! Constant folding of no-op scalar math.
//!
//! Rewrites scalar ops whose constant makes them mathematically a
//! no-op into `identity` (which the identity pass then removes):
//!
//! * `mul_scalar c=1`, `div_scalar c=1`, `pow_scalar p=1` — exact for
//!   every IEEE value including NaN and signed zero,
//! * `clip` with neither bound set,
//! * `columns_agg` over a single float column (`sum`/`min`/`max`
//!   reduce to the column itself; `mean` divides by 1.0, exact).
//!
//! **Why `add_scalar c=0` is NOT folded:** IEEE `-0.0 + 0.0 == +0.0`,
//! so x+0 is not a bitwise identity (same for `sub_scalar 0` and
//! `scale_shift {1, 0}`). The win is negligible; exactness is the
//! contract.
//!
//! **The rounding gate:** the interpreter rounds scalar-math results
//! through f32 to mirror the compiled graph. Folding `mul_scalar 1`
//! away also removes that rounding step, which is only exact when the
//! input is already f32-rounded — i.e. when its producer is a graph
//! node whose registry entry sets `rounds_f32`. Inputs coming straight
//! from the request (raw f64) never qualify.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecDType};
use crate::optim::{names, registry, Pass};

use super::meta_map;

pub struct ConstFold;

impl Pass for ConstFold {
    fn name(&self) -> &'static str {
        "const-fold"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let meta = meta_map(spec);
        // producer op of every node-produced name (owned: the node list
        // is mutated below)
        let producer: HashMap<String, String> =
            spec.nodes.iter().map(|n| (n.id.clone(), n.op.clone())).collect();
        let input_already_rounded = |input: &str| -> bool {
            producer
                .get(input)
                .and_then(|op| registry::lookup(op))
                .map(|i| i.rounds_f32)
                .unwrap_or(false)
        };

        let mut changed = false;
        for node in &mut spec.nodes {
            let a = &node.attrs;
            let no_op = match node.op.as_str() {
                names::MUL_SCALAR | names::DIV_SCALAR => a.opt_f64("c") == Some(1.0),
                names::POW_SCALAR => a.opt_f64("p") == Some(1.0),
                names::CLIP => a.opt_f64("min").is_none() && a.opt_f64("max").is_none(),
                _ => false,
            };
            // these ops round through f32; only fold when that rounding
            // is provably redundant
            let fold_scalar =
                no_op && node.inputs.len() == 1 && input_already_rounded(&node.inputs[0]);

            // columns_agg over one column never rounds — exact whenever
            // the input is already a float (an int input would have been
            // converted to float by the aggregation)
            let fold_agg = node.op == names::COLUMNS_AGG
                && node.inputs.len() == 1
                && meta.get(&node.inputs[0]).map(|&(dt, w)| {
                    dt == SpecDType::F32 && w == node.width
                }) == Some(true);

            if fold_scalar || fold_agg {
                node.op = names::IDENTITY.to_string();
                node.attrs = crate::util::json::Json::object();
                changed = true;
            }
        }
        Ok(changed)
    }
}
