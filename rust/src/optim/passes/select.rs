//! Select/compare simplification.
//!
//! Conditional features export as `select(compare_scalar(x, op, c), a,
//! b)`: an i64 mask column is fully materialised just to steer the
//! select. When the mask has no other consumer and is not a spec
//! output, this pass rewrites the pair into ONE `select_cmp` node that
//! evaluates the predicate inside the select — branchless under the
//! compiled lowering (`jnp.where` over the comparison), one column walk
//! and no mask materialisation in the interpreter — and deletes the
//! dead compare node.
//!
//! Exactness: `select_cmp` replays compare_scalar's arithmetic exactly
//! (both operands rounded through f32, compared in f64; NaN compares
//! false, picking the else branch) and copies branch values raw, like
//! `select`. Masks that are spec outputs or multi-use are left alone —
//! fusing those would duplicate the compare instead of removing it.

use std::collections::HashMap;

use crate::error::Result;
use crate::export::{GraphSpec, SpecNode};
use crate::ops::logical::CmpOp;
use crate::optim::{names, Pass};
use crate::util::json::Json;

use super::{output_set, use_counts};

pub struct SelectCmpFuse;

/// A compare_scalar node able to fold into a consuming select.
fn foldable_compare(node: &SpecNode) -> bool {
    node.op == names::COMPARE_SCALAR
        && node.inputs.len() == 1
        && node.width.is_none()
        && node
            .attrs
            .opt_str("op")
            .map(|o| CmpOp::from_name(o).is_ok())
            .unwrap_or(false)
        && node.attrs.opt_f64("value").is_some()
}

impl Pass for SelectCmpFuse {
    fn name(&self) -> &'static str {
        "select-cmp-fuse"
    }

    fn run(&self, spec: &mut GraphSpec) -> Result<bool> {
        let uses = use_counts(spec);
        let outputs = output_set(spec);
        let compare_at: HashMap<&str, usize> = spec
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| foldable_compare(n))
            .map(|(i, n)| (n.id.as_str(), i))
            .collect();

        let mut removed = vec![false; spec.nodes.len()];
        let mut rewrites: Vec<(usize, SpecNode)> = Vec::new();
        for (si, node) in spec.nodes.iter().enumerate() {
            if node.op != names::SELECT || node.inputs.len() != 3 {
                continue;
            }
            let Some(&ci) = compare_at.get(node.inputs[0].as_str()) else {
                continue;
            };
            let cmp = &spec.nodes[ci];
            // the mask must die with the fusion, or there is no win
            if removed[ci]
                || outputs.contains(&cmp.id)
                || uses.get(&cmp.id).copied().unwrap_or(0) != 1
            {
                continue;
            }
            let mut attrs = Json::object();
            attrs.set("op", cmp.attrs.req_str("op")?.to_string());
            attrs.set("value", cmp.attrs.req_f64("value")?);
            rewrites.push((
                si,
                SpecNode {
                    id: node.id.clone(),
                    op: names::SELECT_CMP.to_string(),
                    inputs: vec![
                        cmp.inputs[0].clone(),
                        node.inputs[1].clone(),
                        node.inputs[2].clone(),
                    ],
                    attrs,
                    dtype: node.dtype,
                    width: node.width,
                    lanes: vec![],
                },
            ));
            removed[ci] = true;
        }

        if rewrites.is_empty() {
            return Ok(false);
        }
        for (i, node) in rewrites {
            spec.nodes[i] = node;
        }
        let mut keep = removed.iter().map(|r| !r);
        spec.nodes.retain(|_| keep.next().unwrap());
        Ok(true)
    }
}
