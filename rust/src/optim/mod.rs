//! GraphSpec optimizer — a pass-based IR optimization layer between the
//! fitted pipeline and the executable graph.
//!
//! `SpecBuilder` emits specs verbatim: one node per transformer op, an
//! `identity` node per pass-through output, repeated subexpressions kept,
//! and every offline-only feature still present. Serving pays for all of
//! it on every request. This module rewrites a [`GraphSpec`] into a
//! cheaper, **observably identical** graph:
//!
//! ## Pass catalog
//!
//! | pass | pattern matched | rewrite | lowering |
//! |------|-----------------|---------|----------|
//! | [`passes::DeadNodeElim`] | nodes/inputs/ingress unreachable from outputs | dropped | — |
//! | [`passes::IdentityElim`] | `identity`, no-op `to_f32`/`to_i64` | consumers rewired | — |
//! | [`passes::ConstFold`] | no-op scalar math (`mul_scalar 1`, …) | rewritten to `identity` | — |
//! | [`passes::CommonSubexprElim`] | duplicate (op, inputs, attrs) nodes | redirected to first | — |
//! | [`passes::AffineFuse`] | scalar-affine chains (`add/sub/mul/div_scalar`, `scale_shift`) | one fused `affine` node | fused-scaling Pallas kernel (`kernels.affine_scale`) |
//! | [`passes::IngressFuse`] | single-consumer ingress chains (`trim`→`case`→`hash64`, `split_pad`→`hash64`, …) | one `fused_ingress` node | Rust ingress single-walk (never reaches HLO) |
//! | [`passes::BucketizeMerge`] | `compare_scalar(bucketize(x))` ladders with a dead bucket index | one `multi_bucketize` node | one `_bsearch` + compare in model.py |
//! | [`passes::SelectCmpFuse`] | `select(compare_scalar(x), a, b)` with a dead mask | one branchless `select_cmp` node | `jnp.where` over the comparison |
//! | [`passes::CrossOutputDedup`] | structurally identical ingress/graph/multi-output nodes (multi-variant specs) | redirected to first, outputs aliased | — |
//! | [`passes::MultiLaneBucketize`] | sibling `bucketize`/`compare_scalar`/ladder nodes over one input | one multi-output `multi_bucketize` with a lane per sibling | one shared `_bsearch` + per-lane remap gather / compare |
//!
//! ## Multi-output nodes and lane syntax
//!
//! A graph node may declare named output lanes
//! ([`crate::export::SpecLane`], ops marked
//! [`registry::OpInfo::multi_output`]). Consumers reference a lane as
//! **`"<node_id>.<lane_name>"`**; each lane is *also* bound under its
//! bare `lane_name` in the evaluation env — lane names live in the
//! node/column namespace — which is how a lane keeps serving a spec
//! output whose producing node was merged away (spec outputs are never
//! renamed). In serialized specs the per-node `"lanes"` array is
//! present only on multi-output nodes; pre-lane spec JSON loads
//! unchanged.
//!
//! ## Cost model and driver
//!
//! The registry carries per-op work estimates ([`registry::OpInfo::work`])
//! and [`registry::node_cost`] adds the fixed per-node overhead (column
//! materialisation + env round trip) that fusion passes eliminate.
//! [`PassManager::run`] is a fixpoint driver over that model: it sweeps
//! the pass list, recording per-pass node counts *and* estimated cost,
//! reverts any rewrite that would raise the estimate (an enforced
//! invariant, not an expectation), and re-sweeps until no pass reduces
//! estimated cost (bounded by a small round cap). `kamae optimize
//! --report-json` serialises the resulting trajectory.
//!
//! **Exactness contract:** every pass preserves interpreter outputs
//! *bit-for-bit* (i64 and f32 alike), not merely "within tolerance".
//! The interpreter emulates the compiled graph's f32 arithmetic by
//! rounding float ops through f32; a pass may therefore only remove an
//! op when doing so removes no rounding step (see
//! [`registry::OpInfo::rounds_f32`] and the per-pass comments). The
//! fused `affine` node replays its original chain step-by-step for the
//! same reason. `rust/tests/parity.rs` and `rust/tests/properties.rs`
//! enforce the contract on the MovieLens and LTR pipelines and on
//! random data.
//!
//! Passes never rename entries of `spec.outputs`: output names are an
//! external contract (serving backends map them to engine columns).
//!
//! The `work` constants are hand-set estimates; the
//! [`calibrate`](calibrate::calibrate) harness (`kamae optimize
//! --calibrate`) measures per-op interpreter
//! timings against them and appends the drift trajectory to
//! `BENCH_op_costs.json`, so a follow-up can refit the constants from
//! data instead of judgement.
//!
//! Entry points: [`optimize`] /
//! [`crate::pipeline::PipelineModel::to_graph_spec_opt`] at export time,
//! [`crate::serving::load_backend`] at load time (interpreted/mleap
//! modes), and the `kamae optimize` CLI subcommand.

pub mod calibrate;
pub mod passes;
pub mod registry;

pub use calibrate::{calibrate, CalibrationReport, OpCalibration};
pub use registry::{
    cone_cost, lint_spec, lookup, names, node_cost, spec_cost, variant_costs, Arity, OpInfo,
    Section, VariantCost,
};

use crate::error::{KamaeError, Result};
use crate::export::GraphSpec;
use crate::util::json::Json;

/// How aggressively to optimize an exported spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimizeLevel {
    /// Escape hatch: emit the builder's graph verbatim.
    None,
    /// Exact cleanup passes only (DCE, identity/no-op elimination,
    /// constant folding, CSE, cross-output dedup).
    Basic,
    /// `Basic` plus the fusion passes (scalar-affine chains, ingress
    /// chains, bucketize/select ladders, multi-lane bucketize). The
    /// default.
    #[default]
    Full,
}

impl OptimizeLevel {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizeLevel::None => "none",
            OptimizeLevel::Basic => "basic",
            OptimizeLevel::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Result<OptimizeLevel> {
        Ok(match s {
            "none" | "O0" | "off" => OptimizeLevel::None,
            "basic" | "O1" => OptimizeLevel::Basic,
            "full" | "O2" | "on" => OptimizeLevel::Full,
            other => {
                return Err(KamaeError::InvalidConfig(format!(
                    "unknown optimize level: {other} (expected none|basic|full)"
                )))
            }
        })
    }
}

/// One rewrite pass over a spec. Implementations mutate in place and
/// report whether anything changed.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, spec: &mut GraphSpec) -> Result<bool>;
}

/// Node counts and cost estimates around one pass execution.
#[derive(Debug, Clone)]
pub struct PassStat {
    pub pass: &'static str,
    /// 1-based fixpoint round this execution belongs to.
    pub round: usize,
    pub graph_nodes_before: usize,
    pub graph_nodes_after: usize,
    pub ingress_before: usize,
    pub ingress_after: usize,
    /// Estimated spec cost ([`registry::spec_cost`]) around the pass.
    pub cost_before: u64,
    pub cost_after: u64,
    pub changed: bool,
}

/// Per-pass report of one optimization run.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub spec: String,
    pub level: OptimizeLevel,
    pub stats: Vec<PassStat>,
}

impl OptReport {
    pub fn graph_nodes_before(&self) -> usize {
        self.stats.first().map(|s| s.graph_nodes_before).unwrap_or(0)
    }

    pub fn graph_nodes_after(&self) -> usize {
        self.stats.last().map(|s| s.graph_nodes_after).unwrap_or(0)
    }

    pub fn cost_before(&self) -> u64 {
        self.stats.first().map(|s| s.cost_before).unwrap_or(0)
    }

    pub fn cost_after(&self) -> u64 {
        self.stats.last().map(|s| s.cost_after).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("spec", self.spec.clone());
        j.set("level", self.level.name());
        j.set("graph_nodes_before", self.graph_nodes_before());
        j.set("graph_nodes_after", self.graph_nodes_after());
        j.set("cost_before", self.cost_before() as i64);
        j.set("cost_after", self.cost_after() as i64);
        j.set(
            "passes",
            Json::Array(
                self.stats
                    .iter()
                    .map(|s| {
                        let mut o = Json::object();
                        o.set("pass", s.pass);
                        o.set("round", s.round);
                        o.set("graph_nodes_before", s.graph_nodes_before);
                        o.set("graph_nodes_after", s.graph_nodes_after);
                        o.set("ingress_before", s.ingress_before);
                        o.set("ingress_after", s.ingress_after);
                        o.set("cost_before", s.cost_before as i64);
                        o.set("cost_after", s.cost_after as i64);
                        o.set("changed", s.changed);
                        o
                    })
                    .collect(),
            ),
        );
        j
    }
}

impl std::fmt::Display for OptReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "=== optimize report: {} (level {}) ===", self.spec, self.level.name())?;
        writeln!(
            f,
            "{:<22} {:>12} {:>14} {:>14}",
            "pass", "graph nodes", "ingress nodes", "est. cost"
        )?;
        let mut round = 0;
        for s in &self.stats {
            if s.round != round {
                round = s.round;
                if round > 1 {
                    writeln!(f, "-- round {round} --")?;
                }
            }
            writeln!(
                f,
                "{:<22} {:>5} -> {:<4} {:>6} -> {:<4} {:>6} -> {:<5}{}",
                s.pass,
                s.graph_nodes_before,
                s.graph_nodes_after,
                s.ingress_before,
                s.ingress_after,
                s.cost_before,
                s.cost_after,
                if s.changed { "" } else { "  (no change)" }
            )?;
        }
        write!(
            f,
            "total: {} -> {} graph nodes, est. cost {} -> {}",
            self.graph_nodes_before(),
            self.graph_nodes_after(),
            self.cost_before(),
            self.cost_after()
        )
    }
}

/// Drives an ordered pass list over one spec.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new(passes: Vec<Box<dyn Pass>>) -> PassManager {
        PassManager { passes }
    }

    /// The standard pass pipeline for a level (empty for
    /// [`OptimizeLevel::None`]). Cleanup passes run first (dead and
    /// duplicate work must not inflate fusion chains), then the fusion
    /// passes, then a final DCE sweep for nodes the fusions stranded —
    /// an ordering the cost-guarded fixpoint driver re-runs until the
    /// estimate stops improving.
    pub fn for_level(level: OptimizeLevel) -> PassManager {
        use crate::optim::passes::{
            AffineFuse, BucketizeMerge, CommonSubexprElim, ConstFold, CrossOutputDedup,
            DeadNodeElim, IdentityElim, IngressFuse, MultiLaneBucketize, SelectCmpFuse,
        };
        let mut p: Vec<Box<dyn Pass>> = Vec::new();
        if level != OptimizeLevel::None {
            p.push(Box::new(DeadNodeElim));
            p.push(Box::new(IdentityElim));
            p.push(Box::new(ConstFold));
            // ConstFold rewrites no-ops into `identity`; sweep them up.
            p.push(Box::new(IdentityElim));
            p.push(Box::new(CommonSubexprElim));
            // cross-section / cross-variant dedup after CSE: on merged
            // multi-variant specs the shared prefix collapses here
            p.push(Box::new(CrossOutputDedup));
            if level == OptimizeLevel::Full {
                p.push(Box::new(AffineFuse));
                p.push(Box::new(IngressFuse));
                p.push(Box::new(BucketizeMerge));
                p.push(Box::new(SelectCmpFuse));
                // after the ladder fusions, so fused single-output
                // `multi_bucketize` nodes can join sibling lane groups
                p.push(Box::new(MultiLaneBucketize));
            }
            // CSE/fusion can strand nodes whose consumers were rewritten.
            p.push(Box::new(DeadNodeElim));
        }
        PassManager { passes: p }
    }

    /// Maximum fixpoint rounds — a safety bound; well-behaved pass
    /// suites converge in two (one working round, one no-op round).
    const MAX_ROUNDS: usize = 4;

    /// Cost-model-driven fixpoint driver: sweep the pass list, recording
    /// per-pass node counts and [`spec_cost`] estimates; revert any pass
    /// whose rewrite would *raise* the estimate (enforcing the cost
    /// invariant instead of assuming it); repeat until a full sweep
    /// neither changes the spec nor lowers its estimated cost.
    pub fn run(&self, mut spec: GraphSpec, level: OptimizeLevel) -> Result<(GraphSpec, OptReport)> {
        let mut report =
            OptReport { spec: spec.name.clone(), level, stats: Vec::with_capacity(self.passes.len()) };
        if self.passes.is_empty() {
            return Ok((spec, report));
        }
        for round in 1..=Self::MAX_ROUNDS {
            let round_start_cost = spec_cost(&spec);
            let mut any_change = false;
            for pass in &self.passes {
                let (gb, ib) = (spec.nodes.len(), spec.ingress.len());
                let cb = spec_cost(&spec);
                let snapshot = spec.clone();
                let mut changed = pass.run(&mut spec)?;
                let mut ca = spec_cost(&spec);
                if changed && ca > cb {
                    spec = snapshot;
                    ca = cb;
                    changed = false;
                }
                any_change |= changed;
                report.stats.push(PassStat {
                    pass: pass.name(),
                    round,
                    graph_nodes_before: gb,
                    graph_nodes_after: spec.nodes.len(),
                    ingress_before: ib,
                    ingress_after: spec.ingress.len(),
                    cost_before: cb,
                    cost_after: ca,
                    changed,
                });
            }
            if !any_change || spec_cost(&spec) >= round_start_cost {
                break;
            }
        }
        Ok((spec, report))
    }
}

/// Optimize a spec at the given level. The returned spec is observably
/// identical to the input: same outputs (names, order, dtypes) and
/// bit-identical values under [`crate::export::SpecInterpreter`].
pub fn optimize(spec: GraphSpec, level: OptimizeLevel) -> Result<(GraphSpec, OptReport)> {
    PassManager::for_level(level).run(spec, level)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(OptimizeLevel::parse("none").unwrap(), OptimizeLevel::None);
        assert_eq!(OptimizeLevel::parse("O1").unwrap(), OptimizeLevel::Basic);
        assert_eq!(OptimizeLevel::parse("full").unwrap(), OptimizeLevel::Full);
        assert!(OptimizeLevel::parse("O3").is_err());
        assert_eq!(OptimizeLevel::default(), OptimizeLevel::Full);
    }

    #[test]
    fn none_level_is_a_no_op() {
        let spec = crate::export::GraphSpec {
            name: "t".into(),
            inputs: vec![],
            ingress: vec![],
            graph_inputs: vec![],
            nodes: vec![],
            outputs: vec![],
        };
        let (out, report) = optimize(spec.clone(), OptimizeLevel::None).unwrap();
        assert_eq!(out, spec);
        assert!(report.stats.is_empty());
    }

    #[test]
    fn report_trajectory_is_monotone_and_serialisable() {
        use crate::dataframe::DType;
        use crate::export::{SpecDType, SpecInput, SpecNode};

        // a spec with dead work, an identity, and a fusable ingress chain
        let node = |id: &str, op: &str, inputs: &[&str], attrs: &str, dtype: SpecDType| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        };
        let spec = crate::export::GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "c".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("c_t", names::TRIM, &["c"], "{}", SpecDType::I64),
                node("c_h", names::HASH64, &["c_t"], "{}", SpecDType::I64),
            ],
            graph_inputs: vec!["c_h".into()],
            nodes: vec![
                node("idx", names::HASH_BUCKET, &["c_h"], r#"{"num_bins": 8}"#, SpecDType::I64),
                node("alias", names::IDENTITY, &["idx"], "{}", SpecDType::I64),
                node("dead", names::NOT, &["idx"], "{}", SpecDType::I64),
            ],
            outputs: vec!["alias".into()],
        };
        let (opt, report) = optimize(spec, OptimizeLevel::Full).unwrap();
        assert!(opt.ingress.iter().any(|n| n.op == names::FUSED_INGRESS), "{report}");
        for s in &report.stats {
            assert!(s.graph_nodes_after <= s.graph_nodes_before, "{report}");
            assert!(s.ingress_after <= s.ingress_before, "{report}");
            assert!(s.cost_after <= s.cost_before, "{report}");
        }
        assert!(report.cost_after() < report.cost_before(), "{report}");
        // the JSON record round-trips (the --report-json contract)
        let j = report.to_json();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert!(j.req_array("passes").unwrap().len() >= report.stats.len());
    }
}
