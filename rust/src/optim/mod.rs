//! GraphSpec optimizer — a pass-based IR optimization layer between the
//! fitted pipeline and the executable graph.
//!
//! `SpecBuilder` emits specs verbatim: one node per transformer op, an
//! `identity` node per pass-through output, repeated subexpressions kept,
//! and every offline-only feature still present. Serving pays for all of
//! it on every request. This module rewrites a [`GraphSpec`] into a
//! cheaper, **observably identical** graph:
//!
//! * [`passes::DeadNodeElim`] — drop graph nodes, graph inputs and
//!   ingress nodes not reachable from the spec outputs,
//! * [`passes::IdentityElim`] — remove `identity` and no-op `to_f32`/
//!   `to_i64` cast nodes,
//! * [`passes::ConstFold`] — rewrite provably no-op scalar math
//!   (`mul_scalar 1`, `div_scalar 1`, …) to `identity`,
//! * [`passes::CommonSubexprElim`] — deduplicate nodes computing the
//!   same (op, inputs, attrs) value,
//! * [`passes::AffineFuse`] — collapse chains of scalar-affine ops into
//!   one fused `affine` node (lowered onto the fused-scaling kernel
//!   path by `python/compile/model.py`).
//!
//! **Exactness contract:** every pass preserves interpreter outputs
//! *bit-for-bit* (i64 and f32 alike), not merely "within tolerance".
//! The interpreter emulates the compiled graph's f32 arithmetic by
//! rounding float ops through f32; a pass may therefore only remove an
//! op when doing so removes no rounding step (see
//! [`registry::OpInfo::rounds_f32`] and the per-pass comments). The
//! fused `affine` node replays its original chain step-by-step for the
//! same reason. `rust/tests/parity.rs` and `rust/tests/properties.rs`
//! enforce the contract on the MovieLens and LTR pipelines and on
//! random data.
//!
//! Passes never rename entries of `spec.outputs`: output names are an
//! external contract (serving backends map them to engine columns).
//!
//! Entry points: [`optimize`] /
//! [`crate::pipeline::PipelineModel::to_graph_spec_opt`] at export time,
//! [`crate::serving::load_backend`] at load time (interpreted/mleap
//! modes), and the `kamae optimize` CLI subcommand.

pub mod passes;
pub mod registry;

pub use registry::{lint_spec, lookup, names, Arity, OpInfo, Section};

use crate::error::{KamaeError, Result};
use crate::export::GraphSpec;
use crate::util::json::Json;

/// How aggressively to optimize an exported spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptimizeLevel {
    /// Escape hatch: emit the builder's graph verbatim.
    None,
    /// Exact cleanup passes only (DCE, identity/no-op elimination,
    /// constant folding, CSE).
    Basic,
    /// `Basic` plus scalar-affine chain fusion. The default.
    #[default]
    Full,
}

impl OptimizeLevel {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizeLevel::None => "none",
            OptimizeLevel::Basic => "basic",
            OptimizeLevel::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Result<OptimizeLevel> {
        Ok(match s {
            "none" | "O0" | "off" => OptimizeLevel::None,
            "basic" | "O1" => OptimizeLevel::Basic,
            "full" | "O2" | "on" => OptimizeLevel::Full,
            other => {
                return Err(KamaeError::InvalidConfig(format!(
                    "unknown optimize level: {other} (expected none|basic|full)"
                )))
            }
        })
    }
}

/// One rewrite pass over a spec. Implementations mutate in place and
/// report whether anything changed.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, spec: &mut GraphSpec) -> Result<bool>;
}

/// Node counts around one pass execution.
#[derive(Debug, Clone)]
pub struct PassStat {
    pub pass: &'static str,
    pub graph_nodes_before: usize,
    pub graph_nodes_after: usize,
    pub ingress_before: usize,
    pub ingress_after: usize,
    pub changed: bool,
}

/// Per-pass report of one optimization run.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub spec: String,
    pub level: OptimizeLevel,
    pub stats: Vec<PassStat>,
}

impl OptReport {
    pub fn graph_nodes_before(&self) -> usize {
        self.stats.first().map(|s| s.graph_nodes_before).unwrap_or(0)
    }

    pub fn graph_nodes_after(&self) -> usize {
        self.stats.last().map(|s| s.graph_nodes_after).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("spec", self.spec.clone());
        j.set("level", self.level.name());
        j.set(
            "passes",
            Json::Array(
                self.stats
                    .iter()
                    .map(|s| {
                        let mut o = Json::object();
                        o.set("pass", s.pass);
                        o.set("graph_nodes_before", s.graph_nodes_before);
                        o.set("graph_nodes_after", s.graph_nodes_after);
                        o.set("ingress_before", s.ingress_before);
                        o.set("ingress_after", s.ingress_after);
                        o.set("changed", s.changed);
                        o
                    })
                    .collect(),
            ),
        );
        j
    }
}

impl std::fmt::Display for OptReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "=== optimize report: {} (level {}) ===", self.spec, self.level.name())?;
        writeln!(f, "{:<22} {:>12} {:>14}", "pass", "graph nodes", "ingress nodes")?;
        for s in &self.stats {
            writeln!(
                f,
                "{:<22} {:>5} -> {:<4} {:>6} -> {:<4}{}",
                s.pass,
                s.graph_nodes_before,
                s.graph_nodes_after,
                s.ingress_before,
                s.ingress_after,
                if s.changed { "" } else { "  (no change)" }
            )?;
        }
        write!(
            f,
            "total: {} -> {} graph nodes",
            self.graph_nodes_before(),
            self.graph_nodes_after()
        )
    }
}

/// Drives an ordered pass list over one spec.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new(passes: Vec<Box<dyn Pass>>) -> PassManager {
        PassManager { passes }
    }

    /// The standard pass pipeline for a level (empty for
    /// [`OptimizeLevel::None`]).
    pub fn for_level(level: OptimizeLevel) -> PassManager {
        use crate::optim::passes::{
            AffineFuse, CommonSubexprElim, ConstFold, DeadNodeElim, IdentityElim,
        };
        let mut p: Vec<Box<dyn Pass>> = Vec::new();
        if level != OptimizeLevel::None {
            p.push(Box::new(DeadNodeElim));
            p.push(Box::new(IdentityElim));
            p.push(Box::new(ConstFold));
            // ConstFold rewrites no-ops into `identity`; sweep them up.
            p.push(Box::new(IdentityElim));
            p.push(Box::new(CommonSubexprElim));
            if level == OptimizeLevel::Full {
                p.push(Box::new(AffineFuse));
            }
            // CSE/fusion can strand nodes whose consumers were rewritten.
            p.push(Box::new(DeadNodeElim));
        }
        PassManager { passes: p }
    }

    /// Run every pass in order, collecting per-pass node counts.
    pub fn run(&self, mut spec: GraphSpec, level: OptimizeLevel) -> Result<(GraphSpec, OptReport)> {
        let mut report =
            OptReport { spec: spec.name.clone(), level, stats: Vec::with_capacity(self.passes.len()) };
        for pass in &self.passes {
            let (gb, ib) = (spec.nodes.len(), spec.ingress.len());
            let changed = pass.run(&mut spec)?;
            report.stats.push(PassStat {
                pass: pass.name(),
                graph_nodes_before: gb,
                graph_nodes_after: spec.nodes.len(),
                ingress_before: ib,
                ingress_after: spec.ingress.len(),
                changed,
            });
        }
        Ok((spec, report))
    }
}

/// Optimize a spec at the given level. The returned spec is observably
/// identical to the input: same outputs (names, order, dtypes) and
/// bit-identical values under [`crate::export::SpecInterpreter`].
pub fn optimize(spec: GraphSpec, level: OptimizeLevel) -> Result<(GraphSpec, OptReport)> {
    PassManager::for_level(level).run(spec, level)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(OptimizeLevel::parse("none").unwrap(), OptimizeLevel::None);
        assert_eq!(OptimizeLevel::parse("O1").unwrap(), OptimizeLevel::Basic);
        assert_eq!(OptimizeLevel::parse("full").unwrap(), OptimizeLevel::Full);
        assert!(OptimizeLevel::parse("O3").is_err());
        assert_eq!(OptimizeLevel::default(), OptimizeLevel::Full);
    }

    #[test]
    fn none_level_is_a_no_op() {
        let spec = crate::export::GraphSpec {
            name: "t".into(),
            inputs: vec![],
            ingress: vec![],
            graph_inputs: vec![],
            nodes: vec![],
            outputs: vec![],
        };
        let (out, report) = optimize(spec.clone(), OptimizeLevel::None).unwrap();
        assert_eq!(out, spec);
        assert!(report.stats.is_empty());
    }
}
