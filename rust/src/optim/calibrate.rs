//! Cost-model calibration: measured per-op interpreter timings vs the
//! registry's hand-set work constants.
//!
//! The optimizer's cost model ([`super::registry::node_cost`]) drives
//! real decisions — pass ordering, the fixpoint driver's stop
//! condition, per-variant attribution — yet its per-op `work` units
//! were set by hand. This harness is the first step of the ROADMAP's
//! "fit the constants from measured timings" item: it times every node
//! of a spec with [`SpecInterpreter::profile`] on a synthetic batch,
//! aggregates the timings per op, fits the single global scale
//! (ns per cost unit) that best explains the total, and reports each
//! op's **drift** — how far its measured cost sits from what the
//! registry predicts under that scale. Persistent positive drift means
//! the op's `work` constant is too low (the optimizer under-weights
//! it); negative means too high. The numbers append to
//! `BENCH_op_costs.json` (`kamae optimize --calibrate`), building the
//! trajectory a follow-up will refit the constants from.

use std::collections::BTreeMap;

use crate::dataframe::DataFrame;
use crate::error::Result;
use crate::export::{GraphSpec, SpecInterpreter};
use crate::util::json::Json;

use super::registry::node_cost;

/// One op's measured-vs-estimated calibration row.
#[derive(Debug, Clone)]
pub struct OpCalibration {
    pub op: String,
    /// True for ingress-section ops (string kernels), false for graph
    /// ops (flat-buffer numeric). `element_at`/`slice_list` exist in
    /// both sections with different kernels, so the split is part of
    /// the key.
    pub ingress: bool,
    /// Node instances of this op in the profiled spec.
    pub nodes: usize,
    /// Summed measured time of one evaluation of every instance,
    /// per batch row, ns.
    pub measured_ns_per_row: f64,
    /// Summed registry estimate ([`node_cost`], overhead included) of
    /// the same instances, cost units per row.
    pub estimated_units: u64,
    /// Relative drift of measured vs `scale * estimated`: positive
    /// means the registry under-estimates this op, negative
    /// over-estimates. Percent.
    pub drift_pct: f64,
}

impl OpCalibration {
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("op", self.op.clone());
        j.set("section", if self.ingress { "ingress" } else { "graph" });
        j.set("nodes", self.nodes);
        j.set("measured_ns_per_row", self.measured_ns_per_row);
        j.set("estimated_units", self.estimated_units as i64);
        j.set("drift_pct", self.drift_pct);
        j
    }
}

/// Whole-spec calibration result.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub spec: String,
    /// Rows in the profiled synthetic batch.
    pub rows: usize,
    /// Evaluations averaged per node.
    pub repeats: usize,
    /// Fitted global scale: nanoseconds per registry cost unit (total
    /// measured / total estimated). One scale for the whole spec — the
    /// registry's *relative* magnitudes are what calibration tests.
    pub scale_ns_per_unit: f64,
    /// Per-op rows, worst |drift| first.
    pub ops: Vec<OpCalibration>,
}

impl CalibrationReport {
    /// Machine-readable records for `BENCH_op_costs.json` (one per op,
    /// the shape `util::bench::append_run` nests under `records`).
    pub fn to_records(&self) -> Vec<Json> {
        self.ops.iter().map(OpCalibration::to_json).collect()
    }
}

impl std::fmt::Display for CalibrationReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "=== cost-model calibration: {} ({} rows x {} repeats) ===",
            self.spec, self.rows, self.repeats
        )?;
        writeln!(f, "fitted scale: {:.2} ns/unit", self.scale_ns_per_unit)?;
        writeln!(
            f,
            "{:<22} {:>7} {:>6} {:>14} {:>10} {:>9}",
            "op", "section", "nodes", "measured ns/row", "est units", "drift"
        )?;
        for op in &self.ops {
            writeln!(
                f,
                "{:<22} {:>7} {:>6} {:>14.1} {:>10} {:>8.0}%",
                op.op,
                if op.ingress { "ingress" } else { "graph" },
                op.nodes,
                op.measured_ns_per_row,
                op.estimated_units,
                op.drift_pct
            )?;
        }
        write!(
            f,
            "(positive drift: registry under-estimates the op; refit the \
             OpInfo::work constants from the BENCH_op_costs.json trajectory)"
        )
    }
}

/// Profile `spec` over one synthetic batch and aggregate per-op
/// measured-vs-registry cost drift. `df` must satisfy the spec's input
/// schema (the caller draws it from the matching request pool /
/// synthetic generator).
pub fn calibrate(spec: &GraphSpec, df: &DataFrame, repeats: usize) -> Result<CalibrationReport> {
    let rows = df.num_rows().max(1);
    let interp = SpecInterpreter::new(spec.clone());
    let timings = interp.profile(df, repeats)?;

    // profile() emits ingress nodes then graph nodes, each in spec
    // order — zip the estimates in the same order
    let estimates = spec.ingress.iter().chain(spec.nodes.iter()).map(node_cost);

    // aggregate per (section, op)
    let mut agg: BTreeMap<(bool, String), (usize, f64, u64)> = BTreeMap::new();
    let (mut total_ns, mut total_units) = (0.0f64, 0u64);
    for (t, est) in timings.iter().zip(estimates) {
        let e = agg.entry((t.ingress, t.op.clone())).or_insert((0, 0.0, 0));
        e.0 += 1;
        e.1 += t.mean_ns / rows as f64;
        e.2 += est;
        total_ns += t.mean_ns / rows as f64;
        total_units += est;
    }

    let scale = if total_units == 0 { 0.0 } else { total_ns / total_units as f64 };
    let mut ops: Vec<OpCalibration> = agg
        .into_iter()
        .map(|((ingress, op), (nodes, measured, units))| {
            let expected = scale * units as f64;
            // a zero expectation (empty spec / zero-resolution clock)
            // reports zero drift rather than dividing into inf — the
            // trajectory writer rejects non-finite records
            let drift_pct =
                if expected == 0.0 { 0.0 } else { 100.0 * (measured / expected - 1.0) };
            OpCalibration {
                op,
                ingress,
                nodes,
                measured_ns_per_row: measured,
                estimated_units: units,
                drift_pct,
            }
        })
        .collect();
    ops.sort_by(|a, b| {
        b.drift_pct
            .abs()
            .partial_cmp(&a.drift_pct.abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    Ok(CalibrationReport {
        spec: spec.name.clone(),
        rows,
        repeats,
        scale_ns_per_unit: scale,
        ops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::{Column, DType};
    use crate::export::{SpecDType, SpecInput, SpecNode};

    fn node(id: &str, op: &str, inputs: &[&str], attrs: &str, dtype: SpecDType) -> SpecNode {
        SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        }
    }

    #[test]
    fn calibration_report_is_finite_and_covers_every_op() {
        let rows = 256usize;
        let df = DataFrame::new(vec![
            (
                "x".into(),
                Column::from_f64((0..rows).map(|i| i as f64 * 0.5).collect()),
            ),
            (
                "s".into(),
                Column::from_str(
                    (0..rows).map(|i| format!("  city_{i} ")).collect::<Vec<String>>(),
                ),
            ),
        ])
        .unwrap();
        let spec = GraphSpec {
            name: "cal-test".into(),
            inputs: vec![
                SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                SpecInput { name: "s".into(), dtype: DType::Str, width: None },
            ],
            ingress: vec![
                node("t", "trim", &["s"], "{}", SpecDType::I64),
                node("h", "hash64", &["t"], "{}", SpecDType::I64),
            ],
            graph_inputs: vec!["x".into(), "h".into()],
            nodes: vec![
                node("lx", "log1p", &["x"], "{}", SpecDType::F32),
                node(
                    "bx",
                    "bucketize",
                    &["lx"],
                    r#"{"splits": [0.5, 1.5, 2.5, 3.5]}"#,
                    SpecDType::I64,
                ),
                node("hb", "hash_bucket", &["h"], r#"{"num_bins": 64}"#, SpecDType::I64),
            ],
            outputs: vec!["bx".into(), "hb".into()],
        };
        let report = calibrate(&spec, &df, 5).unwrap();
        assert_eq!(report.rows, rows);
        // every distinct op shows up exactly once
        let mut ops: Vec<&str> = report.ops.iter().map(|o| o.op.as_str()).collect();
        ops.sort_unstable();
        assert_eq!(ops, vec!["bucketize", "hash64", "hash_bucket", "log1p", "trim"]);
        assert!(report.scale_ns_per_unit.is_finite());
        for op in &report.ops {
            assert!(op.measured_ns_per_row.is_finite(), "{}", op.op);
            assert!(op.drift_pct.is_finite(), "{}", op.op);
            assert!(op.estimated_units > 0, "{}", op.op);
            assert_eq!(op.nodes, 1, "{}", op.op);
        }
        // drifts are measured against ONE fitted scale, so they cannot
        // all sit on the same side of zero (the fit balances them) —
        // unless the clock resolved nothing at all
        if report.scale_ns_per_unit > 0.0 {
            let max = report.ops.iter().map(|o| o.drift_pct).fold(f64::MIN, f64::max);
            let min = report.ops.iter().map(|o| o.drift_pct).fold(f64::MAX, f64::min);
            assert!(max >= 0.0 && min <= 0.0, "drift range [{min}, {max}]");
        }
        // records survive the trajectory writer's JSON round trip
        for rec in report.to_records() {
            assert_eq!(Json::parse(&rec.to_string()).unwrap(), rec);
        }
        // the table renders
        let text = report.to_string();
        assert!(text.contains("cost-model calibration"));
        assert!(text.contains("bucketize"));
    }
}
