//! Dtype casting — the engine's implementation of Kamae's
//! `inputDtype`/`outputDtype` transformer parameters.
//!
//! Semantics follow Spark SQL casts: numeric widening/narrowing by value,
//! string→number parses (unparseable → null), number→string canonical
//! form, bool↔number as 0/1. List columns cast element-wise.

use crate::dataframe::{Column, DType, ListColumn};
use crate::error::{KamaeError, Result};

/// Cast a column to the target dtype. No-op (clone) when dtypes match.
pub fn cast(col: &Column, to: &DType) -> Result<Column> {
    if &col.dtype() == to {
        return Ok(col.clone());
    }
    match (col, to) {
        // ---- list → list: element-wise --------------------------------
        (_, DType::List(inner)) if col.dtype().element().is_some() => {
            cast_list(col, inner)
        }
        // ---- scalar → scalar -------------------------------------------
        (_, DType::Bool) => {
            let f = to_f64_vec(col)?;
            Ok(Column::Bool(f.iter().map(|&x| x != 0.0).collect(), col.nulls().cloned()))
        }
        (_, DType::I32) => {
            let f = to_f64_lossy(col)?;
            merge_parse_nulls(col, f.1, Column::I32(f.0.iter().map(|&x| x as i32).collect(), None))
        }
        (_, DType::I64) => {
            // int64 must NOT round-trip through f64 (hash precision)
            if let Column::I32(v, n) = col {
                return Ok(Column::I64(v.iter().map(|&x| x as i64).collect(), n.clone()));
            }
            if let Column::Str(v, _) = col {
                let mut nulls = vec![false; v.len()];
                let data: Vec<i64> = v
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        s.trim().parse::<i64>().unwrap_or_else(|_| {
                            nulls[i] = true;
                            0
                        })
                    })
                    .collect();
                return merge_parse_nulls(col, Some(nulls), Column::I64(data, None));
            }
            let f = to_f64_lossy(col)?;
            merge_parse_nulls(col, f.1, Column::I64(f.0.iter().map(|&x| x as i64).collect(), None))
        }
        (_, DType::F32) => {
            let f = to_f64_lossy(col)?;
            merge_parse_nulls(col, f.1, Column::F32(f.0.iter().map(|&x| x as f32).collect(), None))
        }
        (_, DType::F64) => {
            let f = to_f64_lossy(col)?;
            merge_parse_nulls(col, f.1, Column::F64(f.0, None))
        }
        (_, DType::Str) => Ok(Column::Str(to_string_vec(col)?, col.nulls().cloned())),
        // ---- scalar → list is invalid ----------------------------------
        (_, DType::List(_)) => Err(KamaeError::TypeMismatch {
            expected: to.name(),
            found: col.dtype().name(),
            context: "cast scalar to list".into(),
        }),
    }
}

fn cast_list(col: &Column, inner: &DType) -> Result<Column> {
    macro_rules! go {
        ($l:expr, $mk:expr) => {{
            let scalar = $mk($l.values.clone());
            let cast_values = cast(&scalar, inner)?;
            rebuild_list(cast_values, $l.offsets.clone())
        }};
    }
    match col {
        Column::ListBool(l) => go!(l, Column::from_bool),
        Column::ListI32(l) => go!(l, Column::from_i32),
        Column::ListI64(l) => go!(l, Column::from_i64),
        Column::ListF32(l) => go!(l, Column::from_f32),
        Column::ListF64(l) => go!(l, Column::from_f64),
        Column::ListStr(l) => go!(l, Column::from_str::<String>),
        _ => unreachable!("cast_list called on scalar"),
    }
}

fn rebuild_list(values: Column, offsets: Vec<u32>) -> Result<Column> {
    Ok(match values {
        Column::Bool(v, _) => Column::ListBool(ListColumn { values: v, offsets }),
        Column::I32(v, _) => Column::ListI32(ListColumn { values: v, offsets }),
        Column::I64(v, _) => Column::ListI64(ListColumn { values: v, offsets }),
        Column::F32(v, _) => Column::ListF32(ListColumn { values: v, offsets }),
        Column::F64(v, _) => Column::ListF64(ListColumn { values: v, offsets }),
        Column::Str(v, _) => Column::ListStr(ListColumn { values: v, offsets }),
        other => other,
    })
}

/// Numeric view of a scalar column as f64 (error on strings/lists).
pub fn to_f64_vec(col: &Column) -> Result<Vec<f64>> {
    match col {
        Column::Bool(v, _) => Ok(v.iter().map(|&b| b as u8 as f64).collect()),
        Column::I32(v, _) => Ok(v.iter().map(|&x| x as f64).collect()),
        Column::I64(v, _) => Ok(v.iter().map(|&x| x as f64).collect()),
        Column::F32(v, _) => Ok(v.iter().map(|&x| x as f64).collect()),
        Column::F64(v, _) => Ok(v.clone()),
        other => Err(KamaeError::TypeMismatch {
            expected: "numeric".into(),
            found: other.dtype().name(),
            context: "to_f64_vec".into(),
        }),
    }
}

/// f64 view that also parses strings; returns (data, parse-null mask).
fn to_f64_lossy(col: &Column) -> Result<(Vec<f64>, Option<Vec<bool>>)> {
    if let Column::Str(v, _) = col {
        let mut nulls = vec![false; v.len()];
        let data = v
            .iter()
            .enumerate()
            .map(|(i, s)| {
                s.trim().parse::<f64>().unwrap_or_else(|_| {
                    nulls[i] = true;
                    0.0
                })
            })
            .collect();
        Ok((data, Some(nulls)))
    } else {
        Ok((to_f64_vec(col)?, None))
    }
}

/// Canonical string form of each row (Kamae's cast-to-string). Integers
/// print without decimal point; floats in shortest-roundtrip form (Rust's
/// `{}`); bools as "true"/"false". This is the form the string indexers
/// see when `inputDtype="string"` — the python side never needs to
/// replicate it because indexing happens against exported vocab hashes.
pub fn to_string_vec(col: &Column) -> Result<Vec<String>> {
    match col {
        Column::Bool(v, _) => Ok(v.iter().map(|b| b.to_string()).collect()),
        Column::I32(v, _) => Ok(v.iter().map(|x| x.to_string()).collect()),
        Column::I64(v, _) => Ok(v.iter().map(|x| x.to_string()).collect()),
        Column::F32(v, _) => Ok(v.iter().map(|x| x.to_string()).collect()),
        Column::F64(v, _) => Ok(v.iter().map(|x| x.to_string()).collect()),
        Column::Str(v, _) => Ok(v.clone()),
        other => Err(KamaeError::TypeMismatch {
            expected: "scalar".into(),
            found: other.dtype().name(),
            context: "to_string_vec".into(),
        }),
    }
}

/// Merge parse-nulls with original nulls and finish the cast column.
fn merge_parse_nulls(
    original: &Column,
    parse_nulls: Option<Vec<bool>>,
    mut out: Column,
) -> Result<Column> {
    let merged = match (original.nulls(), parse_nulls) {
        (None, None) => None,
        (Some(a), None) => Some(a.clone()),
        (None, Some(b)) => {
            if b.iter().any(|&x| x) {
                Some(b)
            } else {
                None
            }
        }
        (Some(a), Some(b)) => Some(a.iter().zip(b.iter()).map(|(&x, &y)| x || y).collect()),
    };
    out.set_nulls(merged)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_casts() {
        let c = Column::from_f64(vec![1.9, -2.9, 0.0]);
        let i = cast(&c, &DType::I64).unwrap();
        assert_eq!(i.as_i64().unwrap(), &[1, -2, 0]); // trunc, like Spark
        let b = cast(&c, &DType::Bool).unwrap();
        assert_eq!(b.as_bool().unwrap(), &[true, true, false]);
    }

    #[test]
    fn i32_to_i64_exact() {
        let c = Column::from_i32(vec![i32::MAX, i32::MIN]);
        let i = cast(&c, &DType::I64).unwrap();
        assert_eq!(i.as_i64().unwrap(), &[i32::MAX as i64, i32::MIN as i64]);
    }

    #[test]
    fn string_parses_with_nulls() {
        let c = Column::from_str(vec!["3.5", "oops", " 7 "]);
        let f = cast(&c, &DType::F64).unwrap();
        assert_eq!(f.as_f64().unwrap()[0], 3.5);
        assert_eq!(f.as_f64().unwrap()[2], 7.0);
        assert!(f.is_null(1));
        let i = cast(&c, &DType::I64).unwrap();
        assert!(i.is_null(0)); // "3.5" is not an int64
        assert_eq!(i.as_i64().unwrap()[2], 7);
    }

    #[test]
    fn to_string_canonical() {
        let c = Column::from_i64(vec![42]);
        assert_eq!(cast(&c, &DType::Str).unwrap().as_str().unwrap()[0], "42");
        let f = Column::from_f64(vec![1.5]);
        assert_eq!(cast(&f, &DType::Str).unwrap().as_str().unwrap()[0], "1.5");
        let b = Column::from_bool(vec![true]);
        assert_eq!(cast(&b, &DType::Str).unwrap().as_str().unwrap()[0], "true");
    }

    #[test]
    fn list_casts_elementwise() {
        let c = Column::from_i64_rows(vec![vec![1, 2], vec![3]]);
        let f = cast(&c, &DType::parse("array<float64>").unwrap()).unwrap();
        let f = f.as_list_f64().unwrap();
        assert_eq!(f.row(0), &[1.0, 2.0]);
        assert_eq!(f.row(1), &[3.0]);
    }

    #[test]
    fn scalar_to_list_rejected() {
        let c = Column::from_i64(vec![1]);
        assert!(cast(&c, &DType::parse("array<int64>").unwrap()).is_err());
    }

    #[test]
    fn preexisting_nulls_survive() {
        let c = Column::from_f64_opt(vec![Some(1.0), None]);
        let i = cast(&c, &DType::I32).unwrap();
        assert!(!i.is_null(0));
        assert!(i.is_null(1));
    }
}
