//! Numeric math kernels (unary, binary, bucketize).
//!
//! All kernels compute in `f64` and return `F64`/`ListF64` columns
//! (`bucketize` returns `I64`). Each enum variant corresponds 1:1 to a
//! GraphSpec op the python compiler implements with the same semantics —
//! parity tests in `rust/tests/parity.rs` hold these two implementations
//! together.

use crate::dataframe::{Column, ListColumn};
use crate::error::{KamaeError, Result};

/// Unary elementwise operations.
#[derive(Debug, Clone, PartialEq)]
pub enum UnaryOp {
    /// log_base(x); base e when `base` is None.
    Log { base: Option<f64> },
    /// log(1 + x) — the paper's "values spanning many orders of magnitude".
    Log1p,
    Exp,
    Sqrt,
    Abs,
    Neg,
    /// 1/x (inf on zero, like Spark's double division).
    Reciprocal,
    Round,
    Floor,
    Ceil,
    Sin,
    Cos,
    Tanh,
    Sigmoid,
    /// Clamp into [min, max] (either side optional).
    Clip { min: Option<f64>, max: Option<f64> },
    /// x^p.
    PowScalar { p: f64 },
    AddScalar { c: f64 },
    SubScalar { c: f64 },
    MulScalar { c: f64 },
    DivScalar { c: f64 },
    /// x * scale + shift — the fused form standard scaling exports
    /// (scale = 1/σ, shift = −μ/σ).
    ScaleShift { scale: f64, shift: f64 },
}

impl UnaryOp {
    /// Scalar kernel body (shared by column kernel, list kernel, and the
    /// row-wise baseline so all agree bit-for-bit).
    #[inline(always)]
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            UnaryOp::Log { base: None } => x.ln(),
            UnaryOp::Log { base: Some(b) } => x.ln() / b.ln(),
            UnaryOp::Log1p => x.ln_1p(),
            UnaryOp::Exp => x.exp(),
            UnaryOp::Sqrt => x.sqrt(),
            UnaryOp::Abs => x.abs(),
            UnaryOp::Neg => -x,
            UnaryOp::Reciprocal => 1.0 / x,
            UnaryOp::Round => {
                // round-half-to-even, matching jnp.round / Spark's bround
                let r = x.round();
                if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
                    r - (x.signum())
                } else {
                    r
                }
            }
            UnaryOp::Floor => x.floor(),
            UnaryOp::Ceil => x.ceil(),
            UnaryOp::Sin => x.sin(),
            UnaryOp::Cos => x.cos(),
            UnaryOp::Tanh => x.tanh(),
            UnaryOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            UnaryOp::Clip { min, max } => {
                let mut y = x;
                if let Some(m) = min {
                    y = y.max(*m);
                }
                if let Some(m) = max {
                    y = y.min(*m);
                }
                y
            }
            UnaryOp::PowScalar { p } => x.powf(*p),
            UnaryOp::AddScalar { c } => x + c,
            UnaryOp::SubScalar { c } => x - c,
            UnaryOp::MulScalar { c } => x * c,
            UnaryOp::DivScalar { c } => x / c,
            UnaryOp::ScaleShift { scale, shift } => x * scale + shift,
        }
    }

    /// GraphSpec op name, routed through the op registry (python side
    /// implements the same table).
    pub fn spec_name(&self) -> &'static str {
        use crate::optim::names as op;
        match self {
            UnaryOp::Log { .. } => op::LOG,
            UnaryOp::Log1p => op::LOG1P,
            UnaryOp::Exp => op::EXP,
            UnaryOp::Sqrt => op::SQRT,
            UnaryOp::Abs => op::ABS,
            UnaryOp::Neg => op::NEG,
            UnaryOp::Reciprocal => op::RECIPROCAL,
            UnaryOp::Round => op::ROUND,
            UnaryOp::Floor => op::FLOOR,
            UnaryOp::Ceil => op::CEIL,
            UnaryOp::Sin => op::SIN,
            UnaryOp::Cos => op::COS,
            UnaryOp::Tanh => op::TANH,
            UnaryOp::Sigmoid => op::SIGMOID,
            UnaryOp::Clip { .. } => op::CLIP,
            UnaryOp::PowScalar { .. } => op::POW_SCALAR,
            UnaryOp::AddScalar { .. } => op::ADD_SCALAR,
            UnaryOp::SubScalar { .. } => op::SUB_SCALAR,
            UnaryOp::MulScalar { .. } => op::MUL_SCALAR,
            UnaryOp::DivScalar { .. } => op::DIV_SCALAR,
            UnaryOp::ScaleShift { .. } => op::SCALE_SHIFT,
        }
    }
}

/// Apply a unary op over a numeric scalar or list column.
pub fn unary(col: &Column, op: &UnaryOp) -> Result<Column> {
    match col {
        Column::ListI32(_) | Column::ListI64(_) | Column::ListF32(_) | Column::ListF64(_)
        | Column::ListBool(_) => {
            let (values, offsets) = list_f64_parts(col)?;
            Ok(Column::ListF64(ListColumn {
                values: values.iter().map(|&x| op.apply(x)).collect(),
                offsets,
            }))
        }
        _ => {
            let data = super::cast::to_f64_vec(col)?;
            Ok(Column::F64(
                data.iter().map(|&x| op.apply(x)).collect(),
                col.nulls().cloned(),
            ))
        }
    }
}

/// Binary elementwise operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Min,
    Max,
    Mod,
}

impl BinOp {
    #[inline(always)]
    pub fn apply(&self, a: f64, b: f64) -> f64 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Pow => a.powf(b),
            BinOp::Min => a.min(b),
            BinOp::Max => a.max(b),
            // Python-style modulo (result has divisor's sign), matching
            // jnp.mod — NOT Rust's `%`.
            BinOp::Mod => a - b * (a / b).floor(),
        }
    }

    pub fn spec_name(&self) -> &'static str {
        use crate::optim::names as op;
        match self {
            BinOp::Add => op::ADD,
            BinOp::Sub => op::SUB,
            BinOp::Mul => op::MUL,
            BinOp::Div => op::DIV,
            BinOp::Pow => op::POW,
            BinOp::Min => op::MIN,
            BinOp::Max => op::MAX,
            BinOp::Mod => op::MOD,
        }
    }

    pub fn from_name(name: &str) -> Result<BinOp> {
        Ok(match name {
            "add" | "+" => BinOp::Add,
            "sub" | "-" => BinOp::Sub,
            "mul" | "*" => BinOp::Mul,
            "div" | "/" => BinOp::Div,
            "pow" | "^" => BinOp::Pow,
            "min" => BinOp::Min,
            "max" => BinOp::Max,
            "mod" | "%" => BinOp::Mod,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown binary op: {other}")))
            }
        })
    }
}

/// Elementwise binary over two columns. Shapes supported:
/// scalar∘scalar, list∘list (identical offsets), list∘scalar and
/// scalar∘list (row-broadcast).
pub fn binary(a: &Column, b: &Column, op: BinOp) -> Result<Column> {
    let a_list = a.dtype().element().is_some();
    let b_list = b.dtype().element().is_some();
    match (a_list, b_list) {
        (false, false) => {
            let (x, y) = (super::cast::to_f64_vec(a)?, super::cast::to_f64_vec(b)?);
            if x.len() != y.len() {
                return Err(KamaeError::LengthMismatch {
                    left: x.len(),
                    right: y.len(),
                    context: format!("binary {}", op.spec_name()),
                });
            }
            let data = x.iter().zip(y.iter()).map(|(&p, &q)| op.apply(p, q)).collect();
            Ok(Column::F64(data, super::merge_nulls(&[a, b])))
        }
        (true, true) => {
            let (xv, xo) = list_f64_parts(a)?;
            let (yv, yo) = list_f64_parts(b)?;
            if xo != yo {
                return Err(KamaeError::LengthMismatch {
                    left: xv.len(),
                    right: yv.len(),
                    context: format!("binary {} on ragged lists", op.spec_name()),
                });
            }
            let values = xv.iter().zip(yv.iter()).map(|(&p, &q)| op.apply(p, q)).collect();
            Ok(Column::ListF64(ListColumn { values, offsets: xo }))
        }
        (true, false) => {
            let (xv, xo) = list_f64_parts(a)?;
            let y = super::cast::to_f64_vec(b)?;
            let mut values = Vec::with_capacity(xv.len());
            for (row, &s) in xo.windows(2).zip(y.iter()) {
                for &p in &xv[row[0] as usize..row[1] as usize] {
                    values.push(op.apply(p, s));
                }
            }
            Ok(Column::ListF64(ListColumn { values, offsets: xo }))
        }
        (false, true) => {
            let x = super::cast::to_f64_vec(a)?;
            let (yv, yo) = list_f64_parts(b)?;
            let mut values = Vec::with_capacity(yv.len());
            for (row, &s) in yo.windows(2).zip(x.iter()) {
                for &q in &yv[row[0] as usize..row[1] as usize] {
                    values.push(op.apply(s, q));
                }
            }
            Ok(Column::ListF64(ListColumn { values, offsets: yo }))
        }
    }
}

/// Bucketize: index of the first split greater than x (Spark's Bucketizer
/// with +/-inf sentinels). `splits` must be strictly increasing. Output
/// indices are in [0, splits.len()].
pub fn bucketize(col: &Column, splits: &[f64]) -> Result<Column> {
    for w in splits.windows(2) {
        if w[0] >= w[1] {
            return Err(KamaeError::InvalidConfig(
                "bucketize splits must be strictly increasing".into(),
            ));
        }
    }
    let idx = |x: f64| -> i64 { splits.partition_point(|&s| s <= x) as i64 };
    if col.dtype().element().is_some() {
        let (values, offsets) = list_f64_parts(col)?;
        Ok(Column::ListI64(ListColumn {
            values: values.iter().map(|&x| idx(x)).collect(),
            offsets,
        }))
    } else {
        let data = super::cast::to_f64_vec(col)?;
        Ok(Column::I64(
            data.iter().map(|&x| idx(x)).collect(),
            col.nulls().cloned(),
        ))
    }
}

/// Flat f64 view of any numeric list column plus its offsets.
pub fn list_f64_parts(col: &Column) -> Result<(Vec<f64>, Vec<u32>)> {
    match col {
        Column::ListBool(l) => Ok((
            l.values.iter().map(|&b| b as u8 as f64).collect(),
            l.offsets.clone(),
        )),
        Column::ListI32(l) => Ok((
            l.values.iter().map(|&x| x as f64).collect(),
            l.offsets.clone(),
        )),
        Column::ListI64(l) => Ok((
            l.values.iter().map(|&x| x as f64).collect(),
            l.offsets.clone(),
        )),
        Column::ListF32(l) => Ok((
            l.values.iter().map(|&x| x as f64).collect(),
            l.offsets.clone(),
        )),
        Column::ListF64(l) => Ok((l.values.clone(), l.offsets.clone())),
        other => Err(KamaeError::TypeMismatch {
            expected: "numeric list".into(),
            found: other.dtype().name(),
            context: "list_f64_parts".into(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unary_log_and_clip() {
        let c = Column::from_f64(vec![1.0, std::f64::consts::E, 100.0]);
        let l = unary(&c, &UnaryOp::Log { base: None }).unwrap();
        assert!((l.as_f64().unwrap()[1] - 1.0).abs() < 1e-12);
        let l10 = unary(&c, &UnaryOp::Log { base: Some(10.0) }).unwrap();
        assert!((l10.as_f64().unwrap()[2] - 2.0).abs() < 1e-12);
        let cl = unary(&c, &UnaryOp::Clip { min: Some(2.0), max: Some(50.0) }).unwrap();
        assert_eq!(cl.as_f64().unwrap(), &[2.0, std::f64::consts::E, 50.0]);
    }

    #[test]
    fn round_half_even() {
        let c = Column::from_f64(vec![0.5, 1.5, 2.5, -0.5, 2.4]);
        let r = unary(&c, &UnaryOp::Round).unwrap();
        assert_eq!(r.as_f64().unwrap(), &[0.0, 2.0, 2.0, 0.0, 2.0]);
    }

    #[test]
    fn unary_on_int_list() {
        let c = Column::from_i64_rows(vec![vec![1, 4], vec![9]]);
        let s = unary(&c, &UnaryOp::Sqrt).unwrap();
        let s = s.as_list_f64().unwrap();
        assert_eq!(s.row(0), &[1.0, 2.0]);
        assert_eq!(s.row(1), &[3.0]);
    }

    #[test]
    fn binary_shapes() {
        let a = Column::from_f64(vec![1.0, 2.0]);
        let b = Column::from_f64(vec![10.0, 20.0]);
        assert_eq!(
            binary(&a, &b, BinOp::Add).unwrap().as_f64().unwrap(),
            &[11.0, 22.0]
        );
        // list ∘ scalar broadcast
        let l = Column::from_f64_rows(vec![vec![1.0, 2.0], vec![3.0]]);
        let out = binary(&l, &a, BinOp::Mul).unwrap();
        let out = out.as_list_f64().unwrap();
        assert_eq!(out.row(0), &[1.0, 2.0]);
        assert_eq!(out.row(1), &[6.0]);
        // scalar ∘ list broadcast
        let out2 = binary(&a, &l, BinOp::Sub).unwrap();
        assert_eq!(out2.as_list_f64().unwrap().row(0), &[0.0, -1.0]);
    }

    #[test]
    fn binary_mod_matches_python() {
        let a = Column::from_f64(vec![-7.0, 7.0]);
        let b = Column::from_f64(vec![3.0, -3.0]);
        let m = binary(&a, &b, BinOp::Mod).unwrap();
        assert_eq!(m.as_f64().unwrap(), &[2.0, -2.0]); // python -7%3=2, 7%-3=-2
    }

    #[test]
    fn binary_length_mismatch() {
        let a = Column::from_f64(vec![1.0]);
        let b = Column::from_f64(vec![1.0, 2.0]);
        assert!(binary(&a, &b, BinOp::Add).is_err());
    }

    #[test]
    fn bucketize_bounds() {
        let c = Column::from_f64(vec![-5.0, 0.0, 0.5, 1.0, 99.0]);
        let b = bucketize(&c, &[0.0, 1.0]).unwrap();
        assert_eq!(b.as_i64().unwrap(), &[0, 1, 1, 2, 2]);
        assert!(bucketize(&c, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn nulls_propagate() {
        let a = Column::from_f64_opt(vec![Some(1.0), None]);
        let b = Column::from_f64(vec![1.0, 1.0]);
        let out = binary(&a, &b, BinOp::Add).unwrap();
        assert!(out.is_null(1));
        let u = unary(&a, &UnaryOp::Exp).unwrap();
        assert!(u.is_null(1));
    }
}
