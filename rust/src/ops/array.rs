//! Array/sequence kernels — Kamae's "nested-sequence-native" operations.
//!
//! `assemble`/`disassemble` implement the paper's LTR pattern: "selected
//! numerical features are assembled into a single array which is
//! subsequently standard scaled and disassembled into original features".
//! Aggregations reduce a list feature to a scalar; `element_at`/`slice`
//! address fixed positions.

use crate::dataframe::{Column, ListColumn};
use crate::error::{KamaeError, Result};

/// Assemble N numeric scalar columns into a fixed-width ListF64 column
/// (VectorAssembler).
pub fn assemble(cols: &[&Column]) -> Result<Column> {
    if cols.is_empty() {
        return Err(KamaeError::InvalidConfig("assemble of zero columns".into()));
    }
    let views: Vec<Vec<f64>> = cols
        .iter()
        .map(|c| super::cast::to_f64_vec(c))
        .collect::<Result<_>>()?;
    let n = views[0].len();
    for v in &views {
        if v.len() != n {
            return Err(KamaeError::LengthMismatch {
                left: v.len(),
                right: n,
                context: "assemble".into(),
            });
        }
    }
    let w = views.len();
    let mut values = Vec::with_capacity(n * w);
    for i in 0..n {
        for v in &views {
            values.push(v[i]);
        }
    }
    let offsets = (0..=n as u32).map(|i| i * w as u32).collect();
    Ok(Column::ListF64(ListColumn { values, offsets }))
}

/// Disassemble a fixed-width list column into scalar F64 columns
/// (inverse of [`assemble`]).
pub fn disassemble(col: &Column) -> Result<Vec<Column>> {
    let (values, offsets) = super::math::list_f64_parts(col)?;
    let l = ListColumn { values, offsets };
    let w = l.fixed_width().ok_or_else(|| {
        KamaeError::InvalidConfig("disassemble requires a fixed-width list".into())
    })?;
    let n = l.len();
    let mut out = vec![Vec::with_capacity(n); w];
    for row in l.rows() {
        for (j, &x) in row.iter().enumerate() {
            out[j].push(x);
        }
    }
    Ok(out.into_iter().map(Column::from_f64).collect())
}

/// List-level aggregations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ListAgg {
    Sum,
    Mean,
    Min,
    Max,
    /// Number of elements.
    Len,
}

impl ListAgg {
    /// GraphSpec op name — routed through the op registry so the
    /// engine, the interpreter and `model.py` can never drift (the
    /// registry's coverage tests pin all three).
    pub fn spec_name(&self) -> &'static str {
        use crate::optim::names as op;
        match self {
            ListAgg::Sum => op::LIST_SUM,
            ListAgg::Mean => op::LIST_MEAN,
            ListAgg::Min => op::LIST_MIN,
            ListAgg::Max => op::LIST_MAX,
            ListAgg::Len => op::LIST_LEN,
        }
    }

    pub fn from_name(s: &str) -> Result<ListAgg> {
        Ok(match s {
            "sum" => ListAgg::Sum,
            "mean" | "avg" => ListAgg::Mean,
            "min" => ListAgg::Min,
            "max" => ListAgg::Max,
            "len" | "length" | "size" => ListAgg::Len,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown list agg: {other}")))
            }
        })
    }
}

/// Reduce each row's list to a scalar. Empty rows produce the reduction
/// identity (0 for sum/len, NaN for mean/min/max — matching jnp on empty
/// slices is moot because exported graphs only see fixed-width lists).
pub fn aggregate(col: &Column, agg: ListAgg) -> Result<Column> {
    if agg == ListAgg::Len {
        // works for any list dtype incl. strings
        let offsets: &[u32] = match col {
            Column::ListBool(l) => &l.offsets,
            Column::ListI32(l) => &l.offsets,
            Column::ListI64(l) => &l.offsets,
            Column::ListF32(l) => &l.offsets,
            Column::ListF64(l) => &l.offsets,
            Column::ListStr(l) => &l.offsets,
            other => {
                return Err(KamaeError::TypeMismatch {
                    expected: "list".into(),
                    found: other.dtype().name(),
                    context: "list_len".into(),
                })
            }
        };
        return Ok(Column::I64(
            offsets.windows(2).map(|w| (w[1] - w[0]) as i64).collect(),
            None,
        ));
    }
    let (values, offsets) = super::math::list_f64_parts(col)?;
    let l = ListColumn { values, offsets };
    let data = l
        .rows()
        .map(|row| match agg {
            ListAgg::Sum => row.iter().sum(),
            ListAgg::Mean => {
                if row.is_empty() {
                    f64::NAN
                } else {
                    row.iter().sum::<f64>() / row.len() as f64
                }
            }
            ListAgg::Min => row.iter().copied().fold(f64::INFINITY, f64::min),
            ListAgg::Max => row.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            ListAgg::Len => unreachable!(),
        })
        .collect();
    Ok(Column::F64(data, None))
}

/// Element at fixed position `idx` of each row (negative = from the end).
/// Out-of-bounds rows become null.
pub fn element_at(col: &Column, idx: i64) -> Result<Column> {
    macro_rules! gather {
        ($l:expr, $variant:ident, $default:expr) => {{
            let mut nulls = vec![false; $l.len()];
            let data = $l
                .rows()
                .enumerate()
                .map(|(i, row)| {
                    let j = if idx < 0 { row.len() as i64 + idx } else { idx };
                    if (0..row.len() as i64).contains(&j) {
                        row[j as usize].clone()
                    } else {
                        nulls[i] = true;
                        $default
                    }
                })
                .collect();
            let mask = if nulls.iter().any(|&b| b) { Some(nulls) } else { None };
            Ok(Column::$variant(data, mask))
        }};
    }
    match col {
        Column::ListBool(l) => gather!(l, Bool, false),
        Column::ListI32(l) => gather!(l, I32, 0),
        Column::ListI64(l) => gather!(l, I64, 0),
        Column::ListF32(l) => gather!(l, F32, 0.0),
        Column::ListF64(l) => gather!(l, F64, 0.0),
        Column::ListStr(l) => gather!(l, Str, String::new()),
        other => Err(KamaeError::TypeMismatch {
            expected: "list".into(),
            found: other.dtype().name(),
            context: "element_at".into(),
        }),
    }
}

/// Row-wise cosine similarity between two fixed-width numeric vector
/// columns (Kamae's `CosineSimilarityTransformer`). Zero vectors yield 0.
pub fn cosine_similarity(a: &Column, b: &Column) -> Result<Column> {
    let (av, ao) = super::math::list_f64_parts(a)?;
    let (bv, bo) = super::math::list_f64_parts(b)?;
    if ao != bo {
        return Err(KamaeError::LengthMismatch {
            left: av.len(),
            right: bv.len(),
            context: "cosine_similarity".into(),
        });
    }
    let la = ListColumn { values: av, offsets: ao };
    let lb = ListColumn { values: bv, offsets: bo };
    let data = la
        .rows()
        .zip(lb.rows())
        .map(|(x, y)| {
            let dot: f64 = x.iter().zip(y.iter()).map(|(p, q)| p * q).sum();
            let nx: f64 = x.iter().map(|p| p * p).sum::<f64>().sqrt();
            let ny: f64 = y.iter().map(|q| q * q).sum::<f64>().sqrt();
            if nx == 0.0 || ny == 0.0 {
                0.0
            } else {
                dot / (nx * ny)
            }
        })
        .collect();
    Ok(Column::F64(data, None))
}

/// Per-row slice `[start, start+len)` of each list (clamped to row size).
pub fn slice_list(col: &Column, start: usize, len: usize) -> Result<Column> {
    macro_rules! sl {
        ($l:expr, $variant:ident) => {{
            let mut values = Vec::new();
            let mut offsets = Vec::with_capacity($l.len() + 1);
            offsets.push(0u32);
            for row in $l.rows() {
                let s = start.min(row.len());
                let e = (start + len).min(row.len());
                values.extend_from_slice(&row[s..e]);
                offsets.push(values.len() as u32);
            }
            Ok(Column::$variant(ListColumn { values, offsets }))
        }};
    }
    match col {
        Column::ListBool(l) => sl!(l, ListBool),
        Column::ListI32(l) => sl!(l, ListI32),
        Column::ListI64(l) => sl!(l, ListI64),
        Column::ListF32(l) => sl!(l, ListF32),
        Column::ListF64(l) => sl!(l, ListF64),
        Column::ListStr(l) => sl!(l, ListStr),
        other => Err(KamaeError::TypeMismatch {
            expected: "list".into(),
            found: other.dtype().name(),
            context: "slice_list".into(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_disassemble_roundtrip() {
        let a = Column::from_f64(vec![1.0, 2.0]);
        let b = Column::from_i64(vec![10, 20]);
        let v = assemble(&[&a, &b]).unwrap();
        let l = v.as_list_f64().unwrap();
        assert_eq!(l.row(0), &[1.0, 10.0]);
        assert_eq!(l.row(1), &[2.0, 20.0]);
        let parts = disassemble(&v).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].as_f64().unwrap(), &[1.0, 2.0]);
        assert_eq!(parts[1].as_f64().unwrap(), &[10.0, 20.0]);
    }

    #[test]
    fn disassemble_requires_fixed_width() {
        let ragged = Column::from_f64_rows(vec![vec![1.0], vec![1.0, 2.0]]);
        assert!(disassemble(&ragged).is_err());
    }

    #[test]
    fn aggregations() {
        let l = Column::from_f64_rows(vec![vec![1.0, 2.0, 3.0], vec![5.0]]);
        assert_eq!(
            aggregate(&l, ListAgg::Sum).unwrap().as_f64().unwrap(),
            &[6.0, 5.0]
        );
        assert_eq!(
            aggregate(&l, ListAgg::Mean).unwrap().as_f64().unwrap(),
            &[2.0, 5.0]
        );
        assert_eq!(
            aggregate(&l, ListAgg::Max).unwrap().as_f64().unwrap(),
            &[3.0, 5.0]
        );
        assert_eq!(
            aggregate(&l, ListAgg::Len).unwrap().as_i64().unwrap(),
            &[3, 1]
        );
    }

    #[test]
    fn len_on_string_lists() {
        let l = Column::from_str_rows(vec![vec!["a", "b"], vec![]]);
        assert_eq!(aggregate(&l, ListAgg::Len).unwrap().as_i64().unwrap(), &[2, 0]);
    }

    #[test]
    fn element_at_with_negatives_and_oob() {
        let l = Column::from_str_rows(vec![vec!["a", "b"], vec!["c"]]);
        let first = element_at(&l, 0).unwrap();
        assert_eq!(first.as_str().unwrap(), &["a".to_string(), "c".to_string()]);
        let last = element_at(&l, -1).unwrap();
        assert_eq!(last.as_str().unwrap(), &["b".to_string(), "c".to_string()]);
        let oob = element_at(&l, 1).unwrap();
        assert!(!oob.is_null(0));
        assert!(oob.is_null(1));
    }

    #[test]
    fn cosine() {
        let a = Column::from_f64_rows(vec![vec![1.0, 0.0], vec![1.0, 1.0], vec![0.0, 0.0]]);
        let b = Column::from_f64_rows(vec![vec![1.0, 0.0], vec![-1.0, -1.0], vec![1.0, 2.0]]);
        let c = cosine_similarity(&a, &b).unwrap();
        let v = c.as_f64().unwrap();
        assert!((v[0] - 1.0).abs() < 1e-12);
        assert!((v[1] + 1.0).abs() < 1e-12);
        assert_eq!(v[2], 0.0); // zero vector
    }

    #[test]
    fn slicing() {
        let l = Column::from_i64_rows(vec![vec![1, 2, 3, 4], vec![5]]);
        let s = slice_list(&l, 1, 2).unwrap();
        let s = s.as_list_i64().unwrap();
        assert_eq!(s.row(0), &[2, 3]);
        assert_eq!(s.row(1), &[] as &[i64]);
    }
}
