//! Vectorised column kernels — the engine's "native transformations".
//!
//! Every transformer in [`crate::transformers`] is a thin configuration
//! struct over a kernel in this module. Kernels operate column-at-a-time
//! over contiguous buffers (the analogue of Spark's Catalyst-optimisable
//! native expressions); the row-at-a-time boxed alternative lives in
//! [`crate::baselines`] and exists only to reproduce the paper's
//! native-vs-UDF comparison (experiment C2).
//!
//! Conventions:
//! * numeric math computes in `f64` and returns `F64` (Spark's `double`
//!   semantics); transformers apply `outputDtype` casts on top;
//! * null masks propagate: any null input row yields a null output row;
//! * list kernels run element-wise over the flat `values` buffer, reusing
//!   the scalar kernel bodies — this is what makes Kamae "nested-sequence
//!   native" without per-row boxing.

pub mod array;
pub mod cast;
pub mod date;
pub mod geo;
pub mod hash;
pub mod logical;
pub mod math;
pub mod regex;
pub mod string_ops;

use crate::dataframe::Column;

/// Merge null masks of several columns (row is null if null in any input).
pub(crate) fn merge_nulls(cols: &[&Column]) -> Option<Vec<bool>> {
    let masks: Vec<&Vec<bool>> = cols.iter().filter_map(|c| c.nulls()).collect();
    if masks.is_empty() {
        return None;
    }
    let n = cols[0].len();
    let mut out = vec![false; n];
    for m in masks {
        for (o, &b) in out.iter_mut().zip(m.iter()) {
            *o |= b;
        }
    }
    Some(out)
}
