//! Geographical kernels (Kamae's geographical transformer family).

use crate::dataframe::Column;
use crate::error::Result;

/// Mean Earth radius in kilometres (IUGG).
pub const EARTH_RADIUS_KM: f64 = 6371.0088;

/// Haversine great-circle distance in km between (lat1,lon1) and
/// (lat2,lon2), all in degrees. Mirrored in the compiled graph as plain
/// trigonometric HLO ops.
#[inline(always)]
pub fn haversine_km(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let (p1, p2) = (lat1.to_radians(), lat2.to_radians());
    let dp = (lat2 - lat1).to_radians();
    let dl = (lon2 - lon1).to_radians();
    let a = (dp / 2.0).sin().powi(2) + p1.cos() * p2.cos() * (dl / 2.0).sin().powi(2);
    2.0 * EARTH_RADIUS_KM * a.sqrt().min(1.0).asin()
}

/// Column kernel over four coordinate columns.
pub fn haversine(
    lat1: &Column,
    lon1: &Column,
    lat2: &Column,
    lon2: &Column,
) -> Result<Column> {
    let a = super::cast::to_f64_vec(lat1)?;
    let b = super::cast::to_f64_vec(lon1)?;
    let c = super::cast::to_f64_vec(lat2)?;
    let d = super::cast::to_f64_vec(lon2)?;
    let data = (0..a.len())
        .map(|i| haversine_km(a[i], b[i], c[i], d[i]))
        .collect();
    Ok(Column::F64(data, super::merge_nulls(&[lat1, lon1, lat2, lon2])))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_distances() {
        // London -> Paris ≈ 344 km
        let d = haversine_km(51.5074, -0.1278, 48.8566, 2.3522);
        assert!((d - 344.0).abs() < 5.0, "d={d}");
        // identical points
        assert_eq!(haversine_km(10.0, 20.0, 10.0, 20.0), 0.0);
        // antipodal ≈ half circumference ≈ 20015 km
        let anti = haversine_km(0.0, 0.0, 0.0, 180.0);
        assert!((anti - 20015.0).abs() < 10.0, "anti={anti}");
    }

    #[test]
    fn column_kernel() {
        let lat1 = Column::from_f64(vec![51.5074]);
        let lon1 = Column::from_f64(vec![-0.1278]);
        let lat2 = Column::from_f64(vec![48.8566]);
        let lon2 = Column::from_f64(vec![2.3522]);
        let d = haversine(&lat1, &lon1, &lat2, &lon2).unwrap();
        assert!((d.as_f64().unwrap()[0] - 344.0).abs() < 5.0);
    }
}
