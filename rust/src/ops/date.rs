//! Date/time kernels.
//!
//! Ingress side: parse `YYYY-MM-DD[ HH:MM:SS]` strings into **days since
//! the Unix epoch** (`i64`) or seconds since epoch. Graph side: all part
//! extraction (year/month/day/weekday/...) and date arithmetic is pure
//! integer math on those epoch values — implemented here with the civil-
//! calendar algorithm (Howard Hinnant's `days_from_civil`/`civil_from_days`)
//! and mirrored op-for-op in `python/compile/model.py` so the compiled
//! graph reproduces it exactly (parity test: `test_date_parts`).

use crate::dataframe::Column;
use crate::error::{KamaeError, Result};

/// days since epoch → (year, month [1,12], day [1,31]).
/// Hinnant's civil_from_days, valid for ±millions of years.
pub fn civil_from_days(z: i64) -> (i64, i64, i64) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = doy - (153 * mp + 2) / 5 + 1; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 }; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// (year, month, day) → days since epoch. Inverse of [`civil_from_days`].
pub fn days_from_civil(y: i64, m: i64, d: i64) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400; // [0, 399]
    let mp = if m > 2 { m - 3 } else { m + 9 };
    let doy = (153 * mp + 2) / 5 + d - 1; // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146_097 + doe - 719_468
}

/// ISO weekday from days since epoch: 1 = Monday ... 7 = Sunday.
/// (1970-01-01 was a Thursday.)
pub fn weekday_from_days(z: i64) -> i64 {
    (z + 3).rem_euclid(7) + 1
}

/// Day of year [1, 366].
pub fn day_of_year(z: i64) -> i64 {
    let (y, _, _) = civil_from_days(z);
    z - days_from_civil(y, 1, 1) + 1
}

/// Parse "YYYY-MM-DD" (optionally with a time part after ' ' or 'T',
/// which is ignored) into days since epoch. Unparseable → None.
pub fn parse_date(s: &str) -> Option<i64> {
    let s = s.trim();
    let date_part = s.split(|c| c == ' ' || c == 'T').next()?;
    let mut it = date_part.split('-');
    // leading '-' for negative years is not supported (not in any config)
    let y: i64 = it.next()?.parse().ok()?;
    let m: i64 = it.next()?.parse().ok()?;
    let d: i64 = it.next()?.parse().ok()?;
    if it.next().is_some() || !(1..=12).contains(&m) || !(1..=31).contains(&d) {
        return None;
    }
    // reject days invalid for the month (roundtrip check)
    let days = days_from_civil(y, m, d);
    let (ry, rm, rd) = civil_from_days(days);
    if (ry, rm, rd) != (y, m, d) {
        return None;
    }
    Some(days)
}

/// Parse "YYYY-MM-DD HH:MM:SS" (or with 'T') into seconds since epoch.
/// A bare date parses as midnight. Unparseable → None.
pub fn parse_timestamp(s: &str) -> Option<i64> {
    let s = s.trim();
    let days = parse_date(s)?;
    let time_part = s
        .split_once(|c| c == ' ' || c == 'T')
        .map(|(_, t)| t)
        .unwrap_or("");
    let secs = if time_part.is_empty() {
        0
    } else {
        let mut it = time_part.split(':');
        let h: i64 = it.next()?.trim().parse().ok()?;
        let m: i64 = it.next()?.parse().ok()?;
        let sec: i64 = it
            .next()
            .map(|x| x.split('.').next().unwrap_or("0").parse().ok())
            .unwrap_or(Some(0))?;
        if it.next().is_some() || !(0..24).contains(&h) || !(0..60).contains(&m) || !(0..60).contains(&sec)
        {
            return None;
        }
        h * 3600 + m * 60 + sec
    };
    Some(days * 86_400 + secs)
}

/// Ingress kernel: string column → days-since-epoch I64 (parse failures
/// become nulls).
pub fn date_to_days(col: &Column) -> Result<Column> {
    let v = col.as_str()?;
    let mut nulls = vec![false; v.len()];
    let data: Vec<i64> = v
        .iter()
        .enumerate()
        .map(|(i, s)| {
            parse_date(s).unwrap_or_else(|| {
                nulls[i] = true;
                0
            })
        })
        .collect();
    let merged = merge_mask(col.nulls(), nulls);
    Ok(Column::I64(data, merged))
}

/// Ingress kernel: string column → seconds-since-epoch I64.
pub fn timestamp_to_seconds(col: &Column) -> Result<Column> {
    let v = col.as_str()?;
    let mut nulls = vec![false; v.len()];
    let data: Vec<i64> = v
        .iter()
        .enumerate()
        .map(|(i, s)| {
            parse_timestamp(s).unwrap_or_else(|| {
                nulls[i] = true;
                0
            })
        })
        .collect();
    let merged = merge_mask(col.nulls(), nulls);
    Ok(Column::I64(data, merged))
}

/// Date parts extractable from an epoch-days column (graph-side op).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatePart {
    Year,
    Month,
    Day,
    /// ISO weekday 1=Mon..7=Sun.
    Weekday,
    DayOfYear,
}

impl DatePart {
    pub fn spec_name(&self) -> &'static str {
        match self {
            DatePart::Year => "year",
            DatePart::Month => "month",
            DatePart::Day => "day",
            DatePart::Weekday => "weekday",
            DatePart::DayOfYear => "day_of_year",
        }
    }

    pub fn from_name(s: &str) -> Result<DatePart> {
        Ok(match s {
            "year" => DatePart::Year,
            "month" => DatePart::Month,
            "day" | "dayofmonth" => DatePart::Day,
            "weekday" | "dayofweek" => DatePart::Weekday,
            "day_of_year" | "dayofyear" => DatePart::DayOfYear,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown date part: {other}")))
            }
        })
    }

    pub fn extract(&self, days: i64) -> i64 {
        match self {
            DatePart::Year => civil_from_days(days).0,
            DatePart::Month => civil_from_days(days).1,
            DatePart::Day => civil_from_days(days).2,
            DatePart::Weekday => weekday_from_days(days),
            DatePart::DayOfYear => day_of_year(days),
        }
    }
}

/// Extract a date part from an epoch-days I64 column.
pub fn extract_part(col: &Column, part: DatePart) -> Result<Column> {
    let v = col.as_i64()?;
    Ok(Column::I64(
        v.iter().map(|&d| part.extract(d)).collect(),
        col.nulls().cloned(),
    ))
}

fn merge_mask(existing: Option<&Vec<bool>>, new: Vec<bool>) -> Option<Vec<bool>> {
    match existing {
        Some(e) => Some(e.iter().zip(new.iter()).map(|(&a, &b)| a || b).collect()),
        None => {
            if new.iter().any(|&b| b) {
                Some(new)
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn civil_roundtrip_sweep() {
        // sweep several eras incl. leap-century boundaries
        for &days in &[-719468i64, -1, 0, 59, 365, 11016, 18262, 20000, 738000] {
            let (y, m, d) = civil_from_days(days);
            assert_eq!(days_from_civil(y, m, d), days, "days={days} ymd={y}-{m}-{d}");
        }
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19723), (2024, 1, 1)); // 2024-01-01
    }

    #[test]
    fn parse_dates() {
        assert_eq!(parse_date("1970-01-01"), Some(0));
        assert_eq!(parse_date("2000-02-29"), Some(days_from_civil(2000, 2, 29)));
        assert_eq!(parse_date("2001-02-29"), None); // not a leap year
        assert_eq!(parse_date("2024-13-01"), None);
        assert_eq!(parse_date("oops"), None);
        assert_eq!(parse_date("2024-06-15 10:30:00"), Some(days_from_civil(2024, 6, 15)));
    }

    #[test]
    fn parse_timestamps() {
        assert_eq!(parse_timestamp("1970-01-01 00:00:01"), Some(1));
        assert_eq!(parse_timestamp("1970-01-02T00:00:00"), Some(86_400));
        assert_eq!(parse_timestamp("1970-01-01"), Some(0));
        assert_eq!(parse_timestamp("1970-01-01 25:00:00"), None);
        assert_eq!(
            parse_timestamp("2024-06-15 10:30:05.123"),
            Some(days_from_civil(2024, 6, 15) * 86_400 + 10 * 3600 + 30 * 60 + 5)
        );
    }

    #[test]
    fn weekday_known_values() {
        assert_eq!(weekday_from_days(0), 4); // 1970-01-01 = Thursday
        assert_eq!(weekday_from_days(parse_date("2024-06-17").unwrap()), 1); // Monday
        assert_eq!(weekday_from_days(parse_date("2024-06-16").unwrap()), 7); // Sunday
        assert_eq!(weekday_from_days(-1), 3); // 1969-12-31 = Wednesday
    }

    #[test]
    fn parts_column() {
        let c = Column::from_str(vec!["2024-02-29", "1999-12-31", "bad"]);
        let days = date_to_days(&c).unwrap();
        assert!(days.is_null(2));
        let year = extract_part(&days, DatePart::Year).unwrap();
        assert_eq!(&year.as_i64().unwrap()[..2], &[2024, 1999]);
        let doy = extract_part(&days, DatePart::DayOfYear).unwrap();
        assert_eq!(doy.as_i64().unwrap()[0], 60); // Feb 29 = day 60
        assert_eq!(doy.as_i64().unwrap()[1], 365);
    }
}
