//! Hashing kernels — the bridge between string features and the compiled
//! numeric graph.
//!
//! HLO has no string dtype, so string-valued features cross the
//! ingress/graph boundary as **FNV-1a 64-bit token hashes** (see DESIGN.md
//! §Substitutions). Everything downstream of the raw hash — bin mixing,
//! modulo, bloom probes — must be reproducible *bit-exactly* inside the
//! compiled graph, so the post-hash arithmetic here is written in the
//! exact operations the JAX side mirrors (`python/compile/kernels/
//! preprocess.py::hash_bucket` / `bloom_probes`):
//!
//! ```text
//! bucket_k(h) = ((h * GOLDEN ⊕ (h >>> 33)) * PHI_k  >>> 33) mod bins
//! ```
//!
//! with all multiplies wrapping on i64 and `>>>` a *logical* shift
//! (jax `lax.shift_right_logical`).

use crate::dataframe::{Column, ListColumn};
use crate::error::Result;

/// FNV-1a 64-bit offset basis / prime.
const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// Odd 64-bit mixing constants (splitmix64 finalizer family). `PHI[k]`
/// parameterises the k-th bloom probe; `PHI[0]` is the plain hash bucket.
pub const MIX: [u64; 8] = [
    0xff51afd7ed558ccd,
    0xc4ceb9fe1a85ec53,
    0x9e3779b97f4a7c15,
    0xbf58476d1ce4e5b9,
    0x94d049bb133111eb,
    0x2545f4914f6cdd1d,
    0xd6e8feb86659fd93,
    0xa5cb9243f0aef993,
];

/// FNV-1a over a string's UTF-8 bytes, as non-negative i64 (top bit
/// cleared so the value survives signed HLO arithmetic and JSON).
pub fn fnv1a64(s: &str) -> i64 {
    let mut h = FNV_OFFSET;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    (h & 0x7fff_ffff_ffff_ffff) as i64
}

/// The graph-side bucket function for probe `k`: deterministic mixing of a
/// token hash into `[0, bins)`. Mirrored bit-exactly by the Pallas kernel.
pub fn bucket(h: i64, k: usize, bins: i64) -> i64 {
    debug_assert!(bins > 0);
    let h = h as u64;
    let mixed = (h.wrapping_mul(MIX[2]) ^ (h >> 33)).wrapping_mul(MIX[k % MIX.len()]) >> 33;
    (mixed % bins as u64) as i64
}

/// Hash a string column to token hashes (the ingress `hash64` op).
pub fn hash64_column(col: &Column) -> Result<Column> {
    match col {
        Column::Str(v, n) => Ok(Column::I64(
            v.iter().map(|s| fnv1a64(s)).collect(),
            n.clone(),
        )),
        Column::ListStr(l) => Ok(Column::ListI64(ListColumn {
            values: l.values.iter().map(|s| fnv1a64(s)).collect(),
            offsets: l.offsets.clone(),
        })),
        // Numeric inputs with inputDtype="string": hash their canonical
        // string form, matching Kamae's cast-then-index behaviour.
        other => {
            let strings = super::cast::to_string_vec(other)?;
            Ok(Column::I64(
                strings.iter().map(|s| fnv1a64(s)).collect(),
                other.nulls().cloned(),
            ))
        }
    }
}

/// Vectorised hash-index (HashIndexTransformer semantics): token hash →
/// bin in `[0, num_bins)`. Works on I64 scalar or list columns.
pub fn hash_bucket_column(col: &Column, num_bins: i64) -> Result<Column> {
    match col {
        Column::I64(v, n) => Ok(Column::I64(
            v.iter().map(|&h| bucket(h, 0, num_bins)).collect(),
            n.clone(),
        )),
        Column::ListI64(l) => Ok(Column::ListI64(ListColumn {
            values: l.values.iter().map(|&h| bucket(h, 0, num_bins)).collect(),
            offsets: l.offsets.clone(),
        })),
        other => hash_bucket_column(&hash64_column(other)?, num_bins),
    }
}

/// Bloom-encode (Serrà & Karatzoglou): `k` probes per token, each in its
/// own bin space, offset so probe j lands in `[j*bins, (j+1)*bins)`.
/// Output is a fixed-width list of `k` indices per row.
pub fn bloom_encode_column(col: &Column, num_hashes: usize, num_bins: i64) -> Result<Column> {
    let hashed = match col {
        Column::I64(..) => col.clone(),
        other => hash64_column(other)?,
    };
    match &hashed {
        Column::I64(v, _) => {
            let mut values = Vec::with_capacity(v.len() * num_hashes);
            for &h in v {
                for k in 0..num_hashes {
                    values.push(k as i64 * num_bins + bucket(h, k, num_bins));
                }
            }
            let offsets = (0..=v.len() as u32).map(|i| i * num_hashes as u32).collect();
            Ok(Column::ListI64(ListColumn { values, offsets }))
        }
        other => Err(crate::error::KamaeError::TypeMismatch {
            expected: "int64 token hashes".into(),
            found: other.dtype().name(),
            context: "bloom_encode".into(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_values() {
        // FNV-1a reference vectors (top bit cleared).
        assert_eq!(fnv1a64(""), (0xcbf29ce484222325u64 & 0x7fffffffffffffff) as i64);
        // stability: same string, same hash, different strings differ
        assert_eq!(fnv1a64("hotel"), fnv1a64("hotel"));
        assert_ne!(fnv1a64("hotel"), fnv1a64("hostel"));
        assert!(fnv1a64("anything") >= 0);
    }

    #[test]
    fn bucket_in_range_and_spread() {
        let bins = 1000;
        let mut seen = std::collections::HashSet::new();
        for i in 0..5000 {
            let b = bucket(fnv1a64(&format!("token{i}")), 0, bins);
            assert!((0..bins).contains(&b));
            seen.insert(b);
        }
        // good mixing: nearly all bins hit
        assert!(seen.len() > 950, "only {} bins hit", seen.len());
    }

    #[test]
    fn probes_are_independent() {
        let h = fnv1a64("pool");
        let b0 = bucket(h, 0, 1 << 20);
        let b1 = bucket(h, 1, 1 << 20);
        assert_ne!(b0, b1);
    }

    #[test]
    fn hash_column_scalar_and_list() {
        let c = Column::from_str(vec!["a", "b"]);
        let h = hash64_column(&c).unwrap();
        assert_eq!(h.as_i64().unwrap()[0], fnv1a64("a"));
        let l = Column::from_str_rows(vec![vec!["a"], vec!["b", "c"]]);
        let hl = hash64_column(&l).unwrap();
        let hl = hl.as_list_i64().unwrap();
        assert_eq!(hl.row(1)[1], fnv1a64("c"));
    }

    #[test]
    fn hash_bucket_from_string_directly() {
        let c = Column::from_str(vec!["x", "y", "x"]);
        let b = hash_bucket_column(&c, 16).unwrap();
        let b = b.as_i64().unwrap();
        assert_eq!(b[0], b[2]);
        assert!(b.iter().all(|&x| (0..16).contains(&x)));
    }

    #[test]
    fn bloom_layout() {
        let c = Column::from_str(vec!["a", "b"]);
        let e = bloom_encode_column(&c, 3, 100).unwrap();
        let e = e.as_list_i64().unwrap();
        assert_eq!(e.len(), 2);
        for row in e.rows() {
            assert_eq!(row.len(), 3);
            for (k, &idx) in row.iter().enumerate() {
                let lo = k as i64 * 100;
                assert!((lo..lo + 100).contains(&idx), "probe {k} idx {idx}");
            }
        }
    }

    #[test]
    fn numeric_input_hashes_via_string_form() {
        // inputDtype="string" on an int column: 42 hashes as "42"
        let c = Column::from_i64(vec![42]);
        let h = hash64_column(&c).unwrap();
        assert_eq!(h.as_i64().unwrap()[0], fnv1a64("42"));
    }
}
