//! String kernels (ingress-side ops).
//!
//! These run in the offline engine *and* verbatim in the serving ingress
//! stage — a single implementation on both sides of the train/serve
//! boundary, which is the paper's core parity argument. They never enter
//! the compiled graph (HLO has no string dtype; see DESIGN.md
//! §Substitutions).

use crate::dataframe::{Column, ListColumn};
use crate::error::{KamaeError, Result};

/// Case transformations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CaseMode {
    Upper,
    Lower,
    Title,
}

pub fn change_case(col: &Column, mode: CaseMode) -> Result<Column> {
    map_str(col, |s| case_value(s, mode))
}

/// Per-value case kernel — shared by [`change_case`] and the fused
/// ingress chain walk in `export::interp`, so the fused and unfused
/// paths are the same code (bit-exactness by construction).
pub fn case_value(s: &str, mode: CaseMode) -> String {
    match mode {
        CaseMode::Upper => s.to_uppercase(),
        CaseMode::Lower => s.to_lowercase(),
        CaseMode::Title => title_case(s),
    }
}

fn title_case(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut at_start = true;
    for c in s.chars() {
        if c.is_whitespace() {
            at_start = true;
            out.push(c);
        } else if at_start {
            out.extend(c.to_uppercase());
            at_start = false;
        } else {
            out.extend(c.to_lowercase());
        }
    }
    out
}

/// Trim whitespace from both ends.
pub fn trim(col: &Column) -> Result<Column> {
    map_str(col, |s| s.trim().to_string())
}

/// Substring by char offsets [start, start+len) (start 0-based; Spark's
/// substring is 1-based but Kamae normalises to 0-based).
pub fn substring(col: &Column, start: usize, len: usize) -> Result<Column> {
    map_str(col, |s| substring_value(s, start, len))
}

/// Per-value substring kernel (shared with the fused ingress walk).
pub fn substring_value(s: &str, start: usize, len: usize) -> String {
    s.chars().skip(start).take(len).collect()
}

/// Literal find/replace (all occurrences).
pub fn replace_literal(col: &Column, from: &str, to: &str) -> Result<Column> {
    map_str(col, |s| s.replace(from, to))
}

/// Left-pad with a char to a minimum width.
pub fn lpad(col: &Column, width: usize, pad: char) -> Result<Column> {
    map_str(col, |s| {
        let n = s.chars().count();
        if n >= width {
            s.clone()
        } else {
            let mut out = String::with_capacity(width);
            out.extend(std::iter::repeat(pad).take(width - n));
            out.push_str(s);
            out
        }
    })
}

/// Concatenate several string columns row-wise with a separator
/// (numeric inputs are cast to their canonical string form first).
pub fn concat_cols(cols: &[&Column], separator: &str) -> Result<Column> {
    if cols.is_empty() {
        return Err(KamaeError::InvalidConfig("concat of zero columns".into()));
    }
    let string_views: Vec<Vec<String>> = cols
        .iter()
        .map(|c| super::cast::to_string_vec(c))
        .collect::<Result<_>>()?;
    let n = string_views[0].len();
    for v in &string_views {
        if v.len() != n {
            return Err(KamaeError::LengthMismatch {
                left: v.len(),
                right: n,
                context: "concat_cols".into(),
            });
        }
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut s = String::new();
        for (j, v) in string_views.iter().enumerate() {
            if j > 0 {
                s.push_str(separator);
            }
            s.push_str(&v[i]);
        }
        out.push(s);
    }
    Ok(Column::Str(out, super::merge_nulls(cols)))
}

/// Split on a literal separator into a ragged list column
/// (StringToStringListTransformer before padding).
pub fn split(col: &Column, separator: &str) -> Result<Column> {
    let v = col.as_str()?;
    let mut values = Vec::new();
    let mut offsets = Vec::with_capacity(v.len() + 1);
    offsets.push(0u32);
    for s in v {
        if !s.is_empty() {
            values.extend(s.split(separator).map(str::to_string));
        }
        offsets.push(values.len() as u32);
    }
    Ok(Column::ListStr(ListColumn { values, offsets }))
}

/// Pad (with `default`) or truncate every row of a list column to exactly
/// `len` elements — the export contract for fixed-shape sequence features.
pub fn pad_list(col: &Column, len: usize, default: &str) -> Result<Column> {
    match col {
        Column::ListStr(l) => {
            let mut values = Vec::with_capacity(l.len() * len);
            for row in l.rows() {
                for i in 0..len {
                    values.push(row.get(i).cloned().unwrap_or_else(|| default.to_string()));
                }
            }
            let offsets = (0..=l.len() as u32).map(|i| i * len as u32).collect();
            Ok(Column::ListStr(ListColumn { values, offsets }))
        }
        Column::ListI64(l) => {
            let d: i64 = default.parse().map_err(|_| {
                KamaeError::InvalidConfig(format!("pad default {default:?} is not int64"))
            })?;
            let mut values = Vec::with_capacity(l.len() * len);
            for row in l.rows() {
                for i in 0..len {
                    values.push(row.get(i).copied().unwrap_or(d));
                }
            }
            let offsets = (0..=l.len() as u32).map(|i| i * len as u32).collect();
            Ok(Column::ListI64(ListColumn { values, offsets }))
        }
        Column::ListF64(l) => {
            let d: f64 = default.parse().map_err(|_| {
                KamaeError::InvalidConfig(format!("pad default {default:?} is not float64"))
            })?;
            let mut values = Vec::with_capacity(l.len() * len);
            for row in l.rows() {
                for i in 0..len {
                    values.push(row.get(i).copied().unwrap_or(d));
                }
            }
            let offsets = (0..=l.len() as u32).map(|i| i * len as u32).collect();
            Ok(Column::ListF64(ListColumn { values, offsets }))
        }
        other => Err(KamaeError::TypeMismatch {
            expected: "list".into(),
            found: other.dtype().name(),
            context: "pad_list".into(),
        }),
    }
}

/// Contains / starts-with / ends-with predicates → Bool column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatchMode {
    Contains,
    StartsWith,
    EndsWith,
}

pub fn string_match(col: &Column, needle: &str, mode: MatchMode) -> Result<Column> {
    let v = col.as_str()?;
    let data = v
        .iter()
        .map(|s| match mode {
            MatchMode::Contains => s.contains(needle),
            MatchMode::StartsWith => s.starts_with(needle),
            MatchMode::EndsWith => s.ends_with(needle),
        })
        .collect();
    Ok(Column::Bool(data, col.nulls().cloned()))
}

/// String length in chars.
pub fn str_len(col: &Column) -> Result<Column> {
    let v = col.as_str()?;
    Ok(Column::I64(
        v.iter().map(|s| s.chars().count() as i64).collect(),
        col.nulls().cloned(),
    ))
}

/// Map a string function over a Str or ListStr column.
fn map_str(col: &Column, f: impl Fn(&String) -> String) -> Result<Column> {
    match col {
        Column::Str(v, n) => Ok(Column::Str(v.iter().map(f).collect(), n.clone())),
        Column::ListStr(l) => Ok(Column::ListStr(ListColumn {
            values: l.values.iter().map(f).collect(),
            offsets: l.offsets.clone(),
        })),
        other => Err(KamaeError::TypeMismatch {
            expected: "string".into(),
            found: other.dtype().name(),
            context: "string op".into(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_modes() {
        let c = Column::from_str(vec!["hello WORLD"]);
        assert_eq!(
            change_case(&c, CaseMode::Upper).unwrap().as_str().unwrap()[0],
            "HELLO WORLD"
        );
        assert_eq!(
            change_case(&c, CaseMode::Lower).unwrap().as_str().unwrap()[0],
            "hello world"
        );
        assert_eq!(
            change_case(&c, CaseMode::Title).unwrap().as_str().unwrap()[0],
            "Hello World"
        );
    }

    #[test]
    fn case_on_list() {
        let c = Column::from_str_rows(vec![vec!["a", "B"]]);
        let u = change_case(&c, CaseMode::Upper).unwrap();
        assert_eq!(u.as_list_str().unwrap().row(0), &["A".to_string(), "B".to_string()]);
    }

    #[test]
    fn split_and_pad() {
        let c = Column::from_str(vec!["Action|Comedy", "Drama", ""]);
        let s = split(&c, "|").unwrap();
        let l = s.as_list_str().unwrap();
        assert_eq!(l.row(0), &["Action".to_string(), "Comedy".to_string()]);
        assert_eq!(l.row(2), &[] as &[String]);
        let p = pad_list(&s, 3, "PAD").unwrap();
        let p = p.as_list_str().unwrap();
        assert_eq!(p.row(0), &["Action".to_string(), "Comedy".to_string(), "PAD".to_string()]);
        assert_eq!(p.row(2), &vec!["PAD".to_string(); 3][..]);
        assert!(p.is_fixed_width(3));
    }

    #[test]
    fn pad_truncates() {
        let c = Column::from_str_rows(vec![vec!["a", "b", "c", "d"]]);
        let p = pad_list(&c, 2, "x").unwrap();
        assert_eq!(p.as_list_str().unwrap().row(0), &["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn concat_mixed_types() {
        let a = Column::from_str(vec!["US", "GB"]);
        let b = Column::from_i64(vec![1, 2]);
        let c = concat_cols(&[&a, &b], "_").unwrap();
        assert_eq!(c.as_str().unwrap(), &["US_1".to_string(), "GB_2".to_string()]);
    }

    #[test]
    fn substring_and_pad_chars() {
        let c = Column::from_str(vec!["héllo"]);
        assert_eq!(substring(&c, 1, 3).unwrap().as_str().unwrap()[0], "éll");
        assert_eq!(lpad(&c, 7, '0').unwrap().as_str().unwrap()[0], "00héllo");
    }

    #[test]
    fn matches_and_len() {
        let c = Column::from_str(vec!["wifi,pool", "spa"]);
        let m = string_match(&c, "pool", MatchMode::Contains).unwrap();
        assert_eq!(m.as_bool().unwrap(), &[true, false]);
        assert_eq!(str_len(&c).unwrap().as_i64().unwrap(), &[9, 3]);
    }

    #[test]
    fn pad_numeric_lists() {
        let c = Column::from_i64_rows(vec![vec![1], vec![2, 3]]);
        let p = pad_list(&c, 2, "-1").unwrap();
        assert_eq!(p.as_list_i64().unwrap().row(0), &[1, -1]);
        assert!(pad_list(&c, 2, "zzz").is_err());
    }
}
