//! A small backtracking regex engine — substrate for the RegexReplace /
//! RegexExtract transformers (no `regex` crate for the library itself in
//! the offline vendor set, and these ops are ingress-side only, so no
//! python mirror is needed).
//!
//! Supported syntax (the subset Kamae's preprocessing configs use):
//! `.` any char · `*` `+` `?` quantifiers (greedy) · `[abc]`, `[a-z]`,
//! `[^...]` classes · `\d \w \s \D \W \S` · escapes `\.` etc ·
//! `( ... )` capture groups · `|` alternation · `^ $` anchors.
//! No lazy quantifiers, backrefs, or lookaround — configs needing those
//! belong in a custom transformer.

use crate::dataframe::Column;
use crate::error::{KamaeError, Result};

/// A compiled regular expression.
#[derive(Debug, Clone)]
pub struct Regex {
    prog: Vec<Node>,
    n_groups: usize,
}

#[derive(Debug, Clone)]
enum Node {
    Char(char),
    Any,
    Class { negated: bool, items: Vec<ClassItem> },
    Star(Box<Node>),
    Plus(Box<Node>),
    Quest(Box<Node>),
    Group(usize, Vec<Vec<Node>>), // group index, alternatives
    StartAnchor,
    EndAnchor,
}

#[derive(Debug, Clone)]
enum ClassItem {
    Char(char),
    Range(char, char),
    Digit(bool),  // \d / \D
    Word(bool),   // \w / \W
    Space(bool),  // \s / \S
}

impl Regex {
    /// Compile a pattern.
    pub fn new(pattern: &str) -> Result<Regex> {
        let mut p = RegexParser {
            chars: pattern.chars().collect(),
            pos: 0,
            group_count: 0,
        };
        let alts = p.alternation()?;
        if p.pos != p.chars.len() {
            return Err(KamaeError::InvalidConfig(format!(
                "regex parse error at char {} in {pattern:?}",
                p.pos
            )));
        }
        let n_groups = p.group_count;
        // wrap top level in group 0
        Ok(Regex { prog: vec![Node::Group(0, alts)], n_groups: n_groups + 1 })
    }

    /// First match in `text`: returns (start, end, group captures).
    pub fn find(&self, text: &str) -> Option<Match> {
        let chars: Vec<char> = text.chars().collect();
        for start in 0..=chars.len() {
            let mut caps = vec![None; self.n_groups];
            if let Some(end) = match_seq(&self.prog, &chars, start, &mut caps) {
                return Some(Match { start, end, caps });
            }
            // ^-anchored patterns can only match at 0
            if matches!(first_atom(&self.prog), Some(Node::StartAnchor)) {
                break;
            }
        }
        None
    }

    /// Whether the pattern matches anywhere in `text`.
    pub fn is_match(&self, text: &str) -> bool {
        self.find(text).is_some()
    }

    /// Replace all non-overlapping matches with `rep` (supports `$1`..`$9`
    /// group references and `$0` for the whole match).
    pub fn replace_all(&self, text: &str, rep: &str) -> String {
        let chars: Vec<char> = text.chars().collect();
        let mut out = String::new();
        let mut i = 0usize;
        while i <= chars.len() {
            let rest: String = chars[i..].iter().collect();
            match self.find(&rest) {
                Some(m) => {
                    // m offsets are relative to rest
                    out.extend(&chars[i..i + m.start]);
                    out.push_str(&expand(rep, &rest, &m));
                    let advance = if m.end > m.start { m.end } else {
                        // empty match: copy one char to guarantee progress
                        if i + m.start < chars.len() {
                            out.push(chars[i + m.start]);
                        }
                        m.end + 1
                    };
                    i += advance.max(1);
                }
                None => {
                    out.extend(&chars[i..]);
                    break;
                }
            }
        }
        out
    }

    /// Extract group `g` of the first match, or `""` if no match.
    pub fn extract(&self, text: &str, g: usize) -> String {
        match self.find(text) {
            Some(m) => m.group(text, g).unwrap_or_default(),
            None => String::new(),
        }
    }
}

/// A regex match: char offsets plus group capture spans.
#[derive(Debug, Clone)]
pub struct Match {
    pub start: usize,
    pub end: usize,
    caps: Vec<Option<(usize, usize)>>,
}

impl Match {
    /// Text of capture group `g` (0 = whole match).
    pub fn group(&self, text: &str, g: usize) -> Option<String> {
        let (s, e) = (*self.caps.get(g)?)?;
        let chars: Vec<char> = text.chars().collect();
        Some(chars[s..e].iter().collect())
    }
}

fn expand(rep: &str, text: &str, m: &Match) -> String {
    let mut out = String::new();
    let mut chars = rep.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '$' {
            if let Some(d) = chars.peek().and_then(|c| c.to_digit(10)) {
                chars.next();
                out.push_str(&m.group(text, d as usize).unwrap_or_default());
                continue;
            }
        }
        out.push(c);
    }
    out
}

fn first_atom(prog: &[Node]) -> Option<&Node> {
    match prog.first() {
        Some(Node::Group(_, alts)) => alts.first().and_then(|a| a.first()),
        n => n,
    }
}

// ---------------------------------------------------------------------------
// matcher: classic backtracking over the node sequence

fn match_seq(
    nodes: &[Node],
    chars: &[char],
    pos: usize,
    caps: &mut Vec<Option<(usize, usize)>>,
) -> Option<usize> {
    let Some((head, rest)) = nodes.split_first() else {
        return Some(pos);
    };
    match head {
        Node::StartAnchor => {
            if pos == 0 {
                match_seq(rest, chars, pos, caps)
            } else {
                None
            }
        }
        Node::EndAnchor => {
            if pos == chars.len() {
                match_seq(rest, chars, pos, caps)
            } else {
                None
            }
        }
        Node::Char(c) => {
            if chars.get(pos) == Some(c) {
                match_seq(rest, chars, pos + 1, caps)
            } else {
                None
            }
        }
        Node::Any => {
            if pos < chars.len() {
                match_seq(rest, chars, pos + 1, caps)
            } else {
                None
            }
        }
        Node::Class { negated, items } => {
            let c = *chars.get(pos)?;
            if class_matches(items, c) != *negated {
                match_seq(rest, chars, pos + 1, caps)
            } else {
                None
            }
        }
        Node::Star(inner) => match_repeat(inner, 0, usize::MAX, rest, chars, pos, caps),
        Node::Plus(inner) => match_repeat(inner, 1, usize::MAX, rest, chars, pos, caps),
        Node::Quest(inner) => match_repeat(inner, 0, 1, rest, chars, pos, caps),
        Node::Group(idx, alts) => {
            for alt in alts {
                let saved = caps.clone();
                if let Some(mid) = match_seq(alt, chars, pos, caps) {
                    caps[*idx] = Some((pos, mid));
                    if let Some(end) = match_seq(rest, chars, mid, caps) {
                        return Some(end);
                    }
                }
                *caps = saved;
            }
            None
        }
    }
}

/// Greedy repeat with backtracking: try the longest count first.
fn match_repeat(
    inner: &Node,
    min: usize,
    max: usize,
    rest: &[Node],
    chars: &[char],
    pos: usize,
    caps: &mut Vec<Option<(usize, usize)>>,
) -> Option<usize> {
    // collect all reachable end positions of inner^k
    let mut ends = vec![pos];
    let mut cur = pos;
    let one = std::slice::from_ref(inner);
    while ends.len() - 1 < max {
        match match_seq(one, chars, cur, caps) {
            Some(next) if next > cur || ends.len() - 1 < min => {
                ends.push(next);
                if next == cur {
                    break; // empty-width inner: stop
                }
                cur = next;
            }
            _ => break,
        }
    }
    if ends.len() - 1 < min {
        return None;
    }
    for &end in ends.iter().skip(min).rev() {
        let saved = caps.clone();
        if let Some(res) = match_seq(rest, chars, end, caps) {
            return Some(res);
        }
        *caps = saved;
    }
    None
}

fn class_matches(items: &[ClassItem], c: char) -> bool {
    items.iter().any(|it| match it {
        ClassItem::Char(x) => c == *x,
        ClassItem::Range(lo, hi) => (*lo..=*hi).contains(&c),
        ClassItem::Digit(pos) => c.is_ascii_digit() == *pos,
        ClassItem::Word(pos) => (c.is_alphanumeric() || c == '_') == *pos,
        ClassItem::Space(pos) => c.is_whitespace() == *pos,
    })
}

// ---------------------------------------------------------------------------
// parser

struct RegexParser {
    chars: Vec<char>,
    pos: usize,
    group_count: usize,
}

impl RegexParser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn alternation(&mut self) -> Result<Vec<Vec<Node>>> {
        let mut alts = vec![self.sequence()?];
        while self.peek() == Some('|') {
            self.bump();
            alts.push(self.sequence()?);
        }
        Ok(alts)
    }

    fn sequence(&mut self) -> Result<Vec<Node>> {
        let mut nodes = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            let atom = self.atom()?;
            let node = match self.peek() {
                Some('*') => {
                    self.bump();
                    Node::Star(Box::new(atom))
                }
                Some('+') => {
                    self.bump();
                    Node::Plus(Box::new(atom))
                }
                Some('?') => {
                    self.bump();
                    Node::Quest(Box::new(atom))
                }
                _ => atom,
            };
            nodes.push(node);
        }
        Ok(nodes)
    }

    fn atom(&mut self) -> Result<Node> {
        match self.bump() {
            Some('.') => Ok(Node::Any),
            Some('^') => Ok(Node::StartAnchor),
            Some('$') => Ok(Node::EndAnchor),
            Some('(') => {
                self.group_count += 1;
                let idx = self.group_count;
                let alts = self.alternation()?;
                if self.bump() != Some(')') {
                    return Err(KamaeError::InvalidConfig("regex: unclosed group".into()));
                }
                Ok(Node::Group(idx, alts))
            }
            Some('[') => self.class(),
            Some('\\') => self.escape(),
            Some(c) if !"*+?".contains(c) => Ok(Node::Char(c)),
            Some(c) => Err(KamaeError::InvalidConfig(format!(
                "regex: dangling quantifier '{c}'"
            ))),
            None => Err(KamaeError::InvalidConfig("regex: unexpected end".into())),
        }
    }

    fn escape(&mut self) -> Result<Node> {
        let c = self
            .bump()
            .ok_or_else(|| KamaeError::InvalidConfig("regex: trailing backslash".into()))?;
        Ok(match c {
            'd' => Node::Class { negated: false, items: vec![ClassItem::Digit(true)] },
            'D' => Node::Class { negated: false, items: vec![ClassItem::Digit(false)] },
            'w' => Node::Class { negated: false, items: vec![ClassItem::Word(true)] },
            'W' => Node::Class { negated: false, items: vec![ClassItem::Word(false)] },
            's' => Node::Class { negated: false, items: vec![ClassItem::Space(true)] },
            'S' => Node::Class { negated: false, items: vec![ClassItem::Space(false)] },
            'n' => Node::Char('\n'),
            't' => Node::Char('\t'),
            'r' => Node::Char('\r'),
            c => Node::Char(c),
        })
    }

    fn class(&mut self) -> Result<Node> {
        let negated = self.peek() == Some('^');
        if negated {
            self.bump();
        }
        let mut items = Vec::new();
        loop {
            match self.bump() {
                None => return Err(KamaeError::InvalidConfig("regex: unclosed class".into())),
                Some(']') => break,
                Some('\\') => {
                    let c = self.bump().ok_or_else(|| {
                        KamaeError::InvalidConfig("regex: trailing backslash in class".into())
                    })?;
                    items.push(match c {
                        'd' => ClassItem::Digit(true),
                        'w' => ClassItem::Word(true),
                        's' => ClassItem::Space(true),
                        'n' => ClassItem::Char('\n'),
                        't' => ClassItem::Char('\t'),
                        c => ClassItem::Char(c),
                    });
                }
                Some(lo) => {
                    if self.peek() == Some('-')
                        && self.chars.get(self.pos + 1).map_or(false, |&c| c != ']')
                    {
                        self.bump(); // '-'
                        let hi = self.bump().unwrap();
                        items.push(ClassItem::Range(lo, hi));
                    } else {
                        items.push(ClassItem::Char(lo));
                    }
                }
            }
        }
        Ok(Node::Class { negated, items })
    }
}

// ---------------------------------------------------------------------------
// column kernels

/// Replace all regex matches in each row.
pub fn regex_replace(col: &Column, re: &Regex, rep: &str) -> Result<Column> {
    match col {
        Column::Str(v, n) => Ok(Column::Str(
            v.iter().map(|s| re.replace_all(s, rep)).collect(),
            n.clone(),
        )),
        Column::ListStr(l) => Ok(Column::ListStr(crate::dataframe::ListColumn {
            values: l.values.iter().map(|s| re.replace_all(s, rep)).collect(),
            offsets: l.offsets.clone(),
        })),
        other => Err(KamaeError::TypeMismatch {
            expected: "string".into(),
            found: other.dtype().name(),
            context: "regex_replace".into(),
        }),
    }
}

/// Extract capture group `g` of the first match per row ("" on no match).
pub fn regex_extract(col: &Column, re: &Regex, g: usize) -> Result<Column> {
    let v = col.as_str()?;
    Ok(Column::Str(
        v.iter().map(|s| re.extract(s, g)).collect(),
        col.nulls().cloned(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_and_classes() {
        let re = Regex::new("ab").unwrap();
        assert!(re.is_match("xxabyy"));
        assert!(!re.is_match("a b"));
        let re = Regex::new(r"[a-c]+\d").unwrap();
        assert!(re.is_match("zzcab9"));
        assert!(!re.is_match("d9"));
        let re = Regex::new("[^0-9]+").unwrap();
        assert_eq!(re.find("123abc").map(|m| (m.start, m.end)), Some((3, 6)));
    }

    #[test]
    fn quantifiers_and_backtracking() {
        let re = Regex::new("a*ab").unwrap();
        assert!(re.is_match("aaab")); // needs backtracking
        let re = Regex::new("colou?r").unwrap();
        assert!(re.is_match("color") && re.is_match("colour"));
        let re = Regex::new("(ab)+c").unwrap();
        assert!(re.is_match("ababc"));
        assert!(!re.is_match("abac"));
    }

    #[test]
    fn anchors() {
        let re = Regex::new("^abc$").unwrap();
        assert!(re.is_match("abc"));
        assert!(!re.is_match("xabc"));
        assert!(!re.is_match("abcx"));
    }

    #[test]
    fn alternation_and_groups() {
        let re = Regex::new("(cat|dog)s?").unwrap();
        let m = re.find("hotdogs!").unwrap();
        assert_eq!(m.group("hotdogs!", 1).unwrap(), "dog");
        assert_eq!(m.group("hotdogs!", 0).unwrap(), "dogs");
    }

    #[test]
    fn replace_with_groups() {
        let re = Regex::new(r"(\d+)-(\d+)").unwrap();
        assert_eq!(re.replace_all("range 3-7 and 10-20", "$2..$1"), "range 7..3 and 20..10");
        let re = Regex::new(r"\s+").unwrap();
        assert_eq!(re.replace_all("a  b\t c", " "), "a b c");
    }

    #[test]
    fn extract_column() {
        let re = Regex::new(r"(\w+)@(\w+)").unwrap();
        let c = Column::from_str(vec!["bob@host", "nope"]);
        let e = regex_extract(&c, &re, 2).unwrap();
        assert_eq!(e.as_str().unwrap(), &["host".to_string(), String::new()]);
    }

    #[test]
    fn replace_column_and_lists() {
        let re = Regex::new(r"\d").unwrap();
        let c = Column::from_str_rows(vec![vec!["a1", "b22"]]);
        let r = regex_replace(&c, &re, "#").unwrap();
        assert_eq!(r.as_list_str().unwrap().row(0), &["a#".to_string(), "b##".to_string()]);
    }

    #[test]
    fn empty_match_progress() {
        let re = Regex::new("x*").unwrap();
        // must terminate and leave non-x chars in place
        // (matches python: re.sub('x*', '-', 'abxxc') == '-a-b--c-')
        assert_eq!(re.replace_all("abxxc", "-"), "-a-b--c-");
    }

    #[test]
    fn parse_errors() {
        assert!(Regex::new("(unclosed").is_err());
        assert!(Regex::new("[unclosed").is_err());
        assert!(Regex::new("*dangling").is_err());
    }
}
