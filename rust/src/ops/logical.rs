//! Logical and comparison kernels (graph-side ops).

use crate::dataframe::{Column, ListColumn};
use crate::error::{KamaeError, Result};

/// Comparison operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    #[inline(always)]
    pub fn apply_f64(&self, a: f64, b: f64) -> bool {
        match self {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            CmpOp::Lt => a < b,
            CmpOp::Le => a <= b,
            CmpOp::Gt => a > b,
            CmpOp::Ge => a >= b,
        }
    }

    pub fn spec_name(&self) -> &'static str {
        match self {
            CmpOp::Eq => "eq",
            CmpOp::Ne => "ne",
            CmpOp::Lt => "lt",
            CmpOp::Le => "le",
            CmpOp::Gt => "gt",
            CmpOp::Ge => "ge",
        }
    }

    pub fn from_name(s: &str) -> Result<CmpOp> {
        Ok(match s {
            "eq" | "==" => CmpOp::Eq,
            "ne" | "!=" => CmpOp::Ne,
            "lt" | "<" => CmpOp::Lt,
            "le" | "<=" => CmpOp::Le,
            "gt" | ">" => CmpOp::Gt,
            "ge" | ">=" => CmpOp::Ge,
            other => return Err(KamaeError::InvalidConfig(format!("unknown cmp op: {other}"))),
        })
    }
}

/// Compare two columns. Numeric comparisons go through f64; string
/// columns support Eq/Ne only (string ordering is locale-trap territory
/// and no Kamae config uses it).
pub fn compare(a: &Column, b: &Column, op: CmpOp) -> Result<Column> {
    if let (Column::Str(x, _), Column::Str(y, _)) = (a, b) {
        if !matches!(op, CmpOp::Eq | CmpOp::Ne) {
            return Err(KamaeError::Unsupported("string ordering comparison".into()));
        }
        if x.len() != y.len() {
            return Err(len_err(x.len(), y.len()));
        }
        let data = x
            .iter()
            .zip(y.iter())
            .map(|(p, q)| match op {
                CmpOp::Eq => p == q,
                _ => p != q,
            })
            .collect();
        return Ok(Column::Bool(data, super::merge_nulls(&[a, b])));
    }
    let x = super::cast::to_f64_vec(a)?;
    let y = super::cast::to_f64_vec(b)?;
    if x.len() != y.len() {
        return Err(len_err(x.len(), y.len()));
    }
    let data = x
        .iter()
        .zip(y.iter())
        .map(|(&p, &q)| op.apply_f64(p, q))
        .collect();
    Ok(Column::Bool(data, super::merge_nulls(&[a, b])))
}

/// Compare a column against a scalar constant.
pub fn compare_scalar(a: &Column, c: f64, op: CmpOp) -> Result<Column> {
    if a.dtype().element().is_some() {
        let (values, offsets) = super::math::list_f64_parts(a)?;
        return Ok(Column::ListBool(ListColumn {
            values: values.iter().map(|&x| op.apply_f64(x, c)).collect(),
            offsets,
        }));
    }
    let x = super::cast::to_f64_vec(a)?;
    Ok(Column::Bool(
        x.iter().map(|&p| op.apply_f64(p, c)).collect(),
        a.nulls().cloned(),
    ))
}

/// String equality against a constant.
pub fn equals_str(a: &Column, needle: &str) -> Result<Column> {
    match a {
        Column::Str(v, n) => Ok(Column::Bool(
            v.iter().map(|s| s == needle).collect(),
            n.clone(),
        )),
        Column::ListStr(l) => Ok(Column::ListBool(ListColumn {
            values: l.values.iter().map(|s| s == needle).collect(),
            offsets: l.offsets.clone(),
        })),
        other => Err(KamaeError::TypeMismatch {
            expected: "string".into(),
            found: other.dtype().name(),
            context: "equals_str".into(),
        }),
    }
}

/// Boolean connectives over two Bool columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoolOp {
    And,
    Or,
    Xor,
}

impl BoolOp {
    pub fn spec_name(&self) -> &'static str {
        match self {
            BoolOp::And => "and",
            BoolOp::Or => "or",
            BoolOp::Xor => "xor",
        }
    }

    pub fn from_name(s: &str) -> Result<BoolOp> {
        Ok(match s {
            "and" => BoolOp::And,
            "or" => BoolOp::Or,
            "xor" => BoolOp::Xor,
            other => return Err(KamaeError::InvalidConfig(format!("unknown bool op: {other}"))),
        })
    }
}

pub fn bool_binary(a: &Column, b: &Column, op: BoolOp) -> Result<Column> {
    let x = a.as_bool()?;
    let y = b.as_bool()?;
    if x.len() != y.len() {
        return Err(len_err(x.len(), y.len()));
    }
    let data = x
        .iter()
        .zip(y.iter())
        .map(|(&p, &q)| match op {
            BoolOp::And => p && q,
            BoolOp::Or => p || q,
            BoolOp::Xor => p ^ q,
        })
        .collect();
    Ok(Column::Bool(data, super::merge_nulls(&[a, b])))
}

pub fn bool_not(a: &Column) -> Result<Column> {
    match a {
        Column::Bool(v, n) => Ok(Column::Bool(v.iter().map(|&b| !b).collect(), n.clone())),
        Column::ListBool(l) => Ok(Column::ListBool(ListColumn {
            values: l.values.iter().map(|&b| !b).collect(),
            offsets: l.offsets.clone(),
        })),
        other => Err(KamaeError::TypeMismatch {
            expected: "bool".into(),
            found: other.dtype().name(),
            context: "not".into(),
        }),
    }
}

/// `if cond then a else b`, elementwise. `a`/`b` must share dtype; cond is
/// Bool. This is the engine half of Kamae's conditional transformers.
pub fn select(cond: &Column, a: &Column, b: &Column) -> Result<Column> {
    let c = cond.as_bool()?;
    if a.dtype() != b.dtype() {
        return Err(KamaeError::TypeMismatch {
            expected: a.dtype().name(),
            found: b.dtype().name(),
            context: "select branches".into(),
        });
    }
    if c.len() != a.len() || a.len() != b.len() {
        return Err(len_err(a.len(), b.len()));
    }
    macro_rules! sel {
        ($variant:ident, $x:expr, $y:expr) => {{
            let data = c
                .iter()
                .zip($x.iter().zip($y.iter()))
                .map(|(&k, (p, q))| if k { p.clone() } else { q.clone() })
                .collect();
            Ok(Column::$variant(data, super::merge_nulls(&[cond, a, b])))
        }};
    }
    match (a, b) {
        (Column::Bool(x, _), Column::Bool(y, _)) => sel!(Bool, x, y),
        (Column::I32(x, _), Column::I32(y, _)) => sel!(I32, x, y),
        (Column::I64(x, _), Column::I64(y, _)) => sel!(I64, x, y),
        (Column::F32(x, _), Column::F32(y, _)) => sel!(F32, x, y),
        (Column::F64(x, _), Column::F64(y, _)) => sel!(F64, x, y),
        (Column::Str(x, _), Column::Str(y, _)) => sel!(Str, x, y),
        _ => Err(KamaeError::Unsupported("select on list columns".into())),
    }
}

fn len_err(left: usize, right: usize) -> KamaeError {
    KamaeError::LengthMismatch { left, right, context: "logical op".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_compare() {
        let a = Column::from_f64(vec![1.0, 2.0, 3.0]);
        let b = Column::from_i64(vec![2, 2, 2]);
        let lt = compare(&a, &b, CmpOp::Lt).unwrap();
        assert_eq!(lt.as_bool().unwrap(), &[true, false, false]);
        let ge = compare(&a, &b, CmpOp::Ge).unwrap();
        assert_eq!(ge.as_bool().unwrap(), &[false, true, true]);
    }

    #[test]
    fn string_compare_eq_only() {
        let a = Column::from_str(vec!["x", "y"]);
        let b = Column::from_str(vec!["x", "z"]);
        let eq = compare(&a, &b, CmpOp::Eq).unwrap();
        assert_eq!(eq.as_bool().unwrap(), &[true, false]);
        assert!(compare(&a, &b, CmpOp::Lt).is_err());
    }

    #[test]
    fn scalar_compare_on_list() {
        let l = Column::from_f64_rows(vec![vec![1.0, 5.0], vec![3.0]]);
        let out = compare_scalar(&l, 2.0, CmpOp::Gt).unwrap();
        match out {
            Column::ListBool(lb) => {
                assert_eq!(lb.row(0), &[false, true]);
                assert_eq!(lb.row(1), &[true]);
            }
            _ => panic!("expected ListBool"),
        }
    }

    #[test]
    fn connectives_and_not() {
        let a = Column::from_bool(vec![true, true, false]);
        let b = Column::from_bool(vec![true, false, false]);
        assert_eq!(
            bool_binary(&a, &b, BoolOp::And).unwrap().as_bool().unwrap(),
            &[true, false, false]
        );
        assert_eq!(
            bool_binary(&a, &b, BoolOp::Xor).unwrap().as_bool().unwrap(),
            &[false, true, false]
        );
        assert_eq!(bool_not(&a).unwrap().as_bool().unwrap(), &[false, false, true]);
    }

    #[test]
    fn select_branches() {
        let c = Column::from_bool(vec![true, false]);
        let a = Column::from_str(vec!["yes", "yes"]);
        let b = Column::from_str(vec!["no", "no"]);
        let s = select(&c, &a, &b).unwrap();
        assert_eq!(s.as_str().unwrap(), &["yes".to_string(), "no".to_string()]);
        // dtype mismatch rejected
        let n = Column::from_i64(vec![1, 2]);
        assert!(select(&c, &a, &n).is_err());
    }
}
