//! Schema-faithful synthetic dataset generators.
//!
//! The environment has no dataset downloads, so the MovieLens rows and
//! Expedia-style Learning-to-Rank traces are generated synthetically with
//! realistic marginals (Zipf-popular ids, log-normal prices, seasonal
//! dates, ragged amenity lists) — the *pipelines* applied to them are
//! identical to the paper's (DESIGN.md §Substitutions).

mod ltr;
mod movielens;

pub use ltr::{gen_ltr, LtrConfig};
pub use movielens::{gen_movielens, MovieLensConfig, GENRES};
