//! Synthetic MovieLens-1M-shaped ratings (Listing 1's input schema:
//! UserID, MovieID, Occupation as int32; Genres as a `|`-joined string).

use crate::dataframe::{Column, DataFrame};
use crate::util::rng::{Rng, Zipf};

/// 18 MovieLens genre labels.
pub const GENRES: [&str; 18] = [
    "Action", "Adventure", "Animation", "Children's", "Comedy", "Crime",
    "Documentary", "Drama", "Fantasy", "Film-Noir", "Horror", "Musical",
    "Mystery", "Romance", "Sci-Fi", "Thriller", "War", "Western",
];

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct MovieLensConfig {
    pub rows: usize,
    pub num_users: usize,
    pub num_movies: usize,
    pub num_occupations: i32,
    pub seed: u64,
}

impl Default for MovieLensConfig {
    fn default() -> Self {
        MovieLensConfig {
            rows: 100_000,
            num_users: 6_040,   // ML-1M marginals
            num_movies: 3_883,
            num_occupations: 21,
            seed: 42,
        }
    }
}

/// Generate a ratings table. Movie popularity is Zipf(1.1) (heavy head,
/// like real ML-1M); each movie has a stable genre set of 1–4 genres;
/// ratings skew positive.
pub fn gen_movielens(cfg: &MovieLensConfig) -> DataFrame {
    let mut rng = Rng::new(cfg.seed);
    let movie_pop = Zipf::new(cfg.num_movies, 1.1);
    let user_pop = Zipf::new(cfg.num_users, 0.8);

    // stable per-movie genre sets, keyed by movie id
    let movie_genres: Vec<String> = (0..cfg.num_movies)
        .map(|m| {
            let mut g = Rng::new(cfg.seed ^ (m as u64).wrapping_mul(0x9E37)); // per-movie
            let k = 1 + g.below(4) as usize;
            let mut picks: Vec<&str> = Vec::with_capacity(k);
            while picks.len() < k {
                let cand = GENRES[g.below(GENRES.len() as u64) as usize];
                if !picks.contains(&cand) {
                    picks.push(cand);
                }
            }
            picks.join("|")
        })
        .collect();

    let mut user_id = Vec::with_capacity(cfg.rows);
    let mut movie_id = Vec::with_capacity(cfg.rows);
    let mut rating = Vec::with_capacity(cfg.rows);
    let mut timestamp = Vec::with_capacity(cfg.rows);
    let mut occupation = Vec::with_capacity(cfg.rows);
    let mut genres = Vec::with_capacity(cfg.rows);

    for _ in 0..cfg.rows {
        let u = user_pop.sample(&mut rng) as i32 + 1;
        let m = movie_pop.sample(&mut rng);
        user_id.push(u);
        movie_id.push(m as i32 + 1);
        // positive-skewed ratings 1..=5
        let r = match rng.below(10) {
            0 => 1.0,
            1 => 2.0,
            2 | 3 => 3.0,
            4..=6 => 4.0,
            _ => 5.0,
        };
        rating.push(r);
        // timestamps across 2000-04 .. 2003-02 (ML-1M window)
        timestamp.push(956_703_932 + rng.below(90_000_000) as i64);
        // occupation correlates weakly with user id (stable per user)
        occupation.push((u as i64 % cfg.num_occupations as i64) as i32);
        genres.push(movie_genres[m].clone());
    }

    DataFrame::new(vec![
        ("UserID".into(), Column::from_i32(user_id)),
        ("MovieID".into(), Column::from_i32(movie_id)),
        ("Rating".into(), Column::from_f64(rating)),
        ("Timestamp".into(), Column::from_i64(timestamp)),
        ("Occupation".into(), Column::from_i32(occupation)),
        ("Genres".into(), Column::from_str(genres)),
    ])
    .expect("columns same length")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_ranges() {
        let cfg = MovieLensConfig { rows: 2000, ..Default::default() };
        let df = gen_movielens(&cfg);
        assert_eq!(df.num_rows(), 2000);
        let users = df.column("UserID").unwrap().as_i32().unwrap();
        assert!(users.iter().all(|&u| u >= 1 && u <= cfg.num_users as i32));
        let ratings = df.column("Rating").unwrap().as_f64().unwrap();
        assert!(ratings.iter().all(|&r| (1.0..=5.0).contains(&r)));
        let genres = df.column("Genres").unwrap().as_str().unwrap();
        assert!(genres.iter().all(|g| !g.is_empty() && g.split('|').count() <= 4));
    }

    #[test]
    fn deterministic_and_popularity_skewed() {
        let cfg = MovieLensConfig { rows: 5000, ..Default::default() };
        let a = gen_movielens(&cfg);
        let b = gen_movielens(&cfg);
        assert_eq!(a, b);
        // head movie should be much more frequent than the median movie
        let movies = a.column("MovieID").unwrap().as_i32().unwrap();
        let mut counts = std::collections::HashMap::new();
        for &m in movies {
            *counts.entry(m).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        assert!(*max > 50, "head count {max}");
    }

    #[test]
    fn genres_stable_per_movie() {
        let cfg = MovieLensConfig { rows: 3000, ..Default::default() };
        let df = gen_movielens(&cfg);
        let movies = df.column("MovieID").unwrap().as_i32().unwrap();
        let genres = df.column("Genres").unwrap().as_str().unwrap();
        let mut seen = std::collections::HashMap::new();
        for (m, g) in movies.iter().zip(genres.iter()) {
            let prev = seen.entry(*m).or_insert_with(|| g.clone());
            assert_eq!(prev, g, "movie {m} has inconsistent genres");
        }
    }
}
