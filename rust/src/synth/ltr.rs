//! Synthetic Expedia-style Learning-to-Rank search traces: the raw
//! feature schema the paper's ~60-transform search-filters pipeline
//! consumes (dates, durations, log-scale numerics, delimited strings,
//! coordinates, categoricals, amenity lists).

use crate::dataframe::{Column, DataFrame};
use crate::util::rng::{Rng, Zipf};

pub const AMENITIES: [&str; 12] = [
    "wifi", "pool", "spa", "parking", "gym", "breakfast", "bar", "pets",
    "beach", "aircon", "kitchen", "washer",
];

pub const COUNTRIES: [&str; 10] =
    ["US", "GB", "DE", "FR", "JP", "BR", "AU", "CA", "IN", "MX"];

/// Destination pool: (name, lat, lon).
pub const DESTINATIONS: [(&str, f64, f64); 8] = [
    ("paris", 48.8566, 2.3522),
    ("london", 51.5074, -0.1278),
    ("new-york", 40.7128, -74.0060),
    ("tokyo", 35.6762, 139.6503),
    ("cancun", 21.1619, -86.8515),
    ("rome", 41.9028, 12.4964),
    ("sydney", -33.8688, 151.2093),
    ("barcelona", 41.3851, 2.1734),
];

#[derive(Debug, Clone)]
pub struct LtrConfig {
    pub rows: usize,
    pub num_properties: usize,
    pub seed: u64,
}

impl Default for LtrConfig {
    fn default() -> Self {
        LtrConfig { rows: 50_000, num_properties: 20_000, seed: 7 }
    }
}

/// One row = one (search, property) impression.
pub fn gen_ltr(cfg: &LtrConfig) -> DataFrame {
    let mut rng = Rng::new(cfg.seed);
    let prop_pop = Zipf::new(cfg.num_properties, 1.05);

    let n = cfg.rows;
    let mut search_ts = Vec::with_capacity(n);
    let mut checkin = Vec::with_capacity(n);
    let mut checkout = Vec::with_capacity(n);
    let mut destination = Vec::with_capacity(n);
    let mut user_country = Vec::with_capacity(n);
    let mut device = Vec::with_capacity(n);
    let mut num_adults = Vec::with_capacity(n);
    let mut num_children = Vec::with_capacity(n);
    let mut property_id = Vec::with_capacity(n);
    let mut price = Vec::with_capacity(n);
    let mut star_rating = Vec::with_capacity(n);
    let mut review_score = Vec::with_capacity(n);
    let mut review_count = Vec::with_capacity(n);
    let mut amenities = Vec::with_capacity(n);
    let mut prop_lat = Vec::with_capacity(n);
    let mut prop_lon = Vec::with_capacity(n);
    let mut dest_lat = Vec::with_capacity(n);
    let mut dest_lon = Vec::with_capacity(n);
    let mut historical_ctr = Vec::with_capacity(n);
    let mut clicked = Vec::with_capacity(n);

    for _ in 0..n {
        // search date in 2024, seasonal peak in summer
        let doy = 1 + ((rng.normal() * 60.0 + 190.0).rem_euclid(365.0)) as i64;
        let days = crate::ops::date::days_from_civil(2024, 1, 1) + doy - 1;
        let (y, m, d) = crate::ops::date::civil_from_days(days);
        search_ts.push(format!(
            "{y:04}-{m:02}-{d:02} {:02}:{:02}:{:02}",
            rng.below(24),
            rng.below(60),
            rng.below(60)
        ));
        let lead = 1 + rng.below(90) as i64;
        let stay = 1 + rng.below(10) as i64;
        let (cy, cm, cd) = crate::ops::date::civil_from_days(days + lead);
        checkin.push(format!("{cy:04}-{cm:02}-{cd:02}"));
        let (oy, om, od) = crate::ops::date::civil_from_days(days + lead + stay);
        checkout.push(format!("{oy:04}-{om:02}-{od:02}"));

        let dest = &DESTINATIONS[rng.below(DESTINATIONS.len() as u64) as usize];
        destination.push(dest.0.to_string());
        dest_lat.push(dest.1);
        dest_lon.push(dest.2);
        user_country.push(COUNTRIES[rng.below(COUNTRIES.len() as u64) as usize].to_string());
        device.push(if rng.bool(0.55) { "mobile" } else { "desktop" }.to_string());
        num_adults.push(1 + rng.below(4) as i64);
        num_children.push(rng.below(3) as i64);

        let p = prop_pop.sample(&mut rng) as i64;
        property_id.push(p);
        // price: log-normal, spans orders of magnitude (paper: log-transformed)
        price.push(rng.log_normal(4.8, 0.9));
        star_rating.push(1.0 + rng.below(9) as f64 * 0.5);
        review_score.push((rng.normal() * 1.2 + 7.8).clamp(1.0, 10.0));
        review_count.push(rng.log_normal(4.0, 1.5) as i64);

        // ragged amenity list, comma-delimited, 1..=7 amenities
        let k = 1 + rng.below(7) as usize;
        let mut picks: Vec<&str> = Vec::with_capacity(k);
        while picks.len() < k {
            let cand = AMENITIES[rng.below(AMENITIES.len() as u64) as usize];
            if !picks.contains(&cand) {
                picks.push(cand);
            }
        }
        amenities.push(picks.join(","));

        // property near its destination
        prop_lat.push(dest.1 + rng.normal() * 0.15);
        prop_lon.push(dest.2 + rng.normal() * 0.15);
        historical_ctr.push((rng.normal() * 0.03 + 0.06).clamp(0.0, 1.0));
        clicked.push(rng.bool(0.08));
    }

    DataFrame::new(vec![
        ("search_ts".into(), Column::from_str(search_ts)),
        ("checkin".into(), Column::from_str(checkin)),
        ("checkout".into(), Column::from_str(checkout)),
        ("destination".into(), Column::from_str(destination)),
        ("user_country".into(), Column::from_str(user_country)),
        ("device".into(), Column::from_str(device)),
        ("num_adults".into(), Column::from_i64(num_adults)),
        ("num_children".into(), Column::from_i64(num_children)),
        ("property_id".into(), Column::from_i64(property_id)),
        ("price".into(), Column::from_f64(price)),
        ("star_rating".into(), Column::from_f64(star_rating)),
        ("review_score".into(), Column::from_f64(review_score)),
        ("review_count".into(), Column::from_i64(review_count)),
        ("amenities".into(), Column::from_str(amenities)),
        ("prop_lat".into(), Column::from_f64(prop_lat)),
        ("prop_lon".into(), Column::from_f64(prop_lon)),
        ("dest_lat".into(), Column::from_f64(dest_lat)),
        ("dest_lon".into(), Column::from_f64(dest_lon)),
        ("historical_ctr".into(), Column::from_f64(historical_ctr)),
        ("clicked".into(), Column::from_bool(clicked)),
    ])
    .expect("columns same length")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_and_validity() {
        let df = gen_ltr(&LtrConfig { rows: 1000, ..Default::default() });
        assert_eq!(df.num_rows(), 1000);
        assert_eq!(df.num_columns(), 20);
        // all dates parse
        for col in ["checkin", "checkout"] {
            let v = df.column(col).unwrap().as_str().unwrap();
            assert!(v.iter().all(|s| crate::ops::date::parse_date(s).is_some()));
        }
        let ts = df.column("search_ts").unwrap().as_str().unwrap();
        assert!(ts.iter().all(|s| crate::ops::date::parse_timestamp(s).is_some()));
        // checkout strictly after checkin
        let ci = df.column("checkin").unwrap().as_str().unwrap();
        let co = df.column("checkout").unwrap().as_str().unwrap();
        for (a, b) in ci.iter().zip(co.iter()) {
            assert!(
                crate::ops::date::parse_date(b).unwrap() > crate::ops::date::parse_date(a).unwrap()
            );
        }
        // prices span orders of magnitude
        let price = df.column("price").unwrap().as_f64().unwrap();
        let (min, max) = price
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &p| (lo.min(p), hi.max(p)));
        assert!(max / min > 20.0, "price range too tight: {min}..{max}");
    }

    #[test]
    fn deterministic() {
        let cfg = LtrConfig { rows: 500, ..Default::default() };
        assert_eq!(gen_ltr(&cfg), gen_ltr(&cfg));
    }
}
