//! GraphSpec interpreter.
//!
//! Executes an exported spec directly on DataFrames: the **ingress**
//! section (string ops) runs through the same `ops::` kernels the engine
//! uses, and the **graph** section is evaluated op-by-op over flat
//! buffers with the same semantics the JAX compiler emits.
//!
//! Three roles:
//! 1. the serving **ingress stage** (`run_ingress`) that feeds the
//!    compiled PJRT graph,
//! 2. the **interpreted serving baseline** (`run`) — columnar but
//!    uncompiled, the ablation point between the MLeap-like row
//!    interpreter and the compiled graph (experiment C3),
//! 3. the **parity oracle**: `run` output must match the compiled
//!    graph's output bit-for-bit on I64 and to f32 rounding on floats.
//!
//! Since the kernel-program rewrite, the interpreter's hot path is the
//! compiled [`super::kernel::KernelProgram`] built once at construction:
//! typed kernels over dense slot-indexed buffers, no per-batch attr
//! parsing or env `HashMap`. The `eval_node` path in this file is kept
//! verbatim as the **differential oracle** ([`SpecInterpreter::new_oracle`])
//! — every kernel is pinned bit-identical to it by tests, properties and
//! the `benches/kernel_program.rs` gate. Specs the kernel compiler does
//! not understand silently fall back to the oracle path, preserving
//! request-time behaviour exactly.

use std::collections::HashMap;

use crate::dataframe::{Column, DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::ops;
use crate::runtime::{Tensor, TensorData};
use crate::util::json::Json;

use super::kernel::KernelProgram;
use super::spec::{Cone, GraphSpec, SpecDType, SpecNode};

/// Flat graph-side value: rows × width buffer of f64 or i64.
#[derive(Debug, Clone)]
enum GVal {
    F(Vec<f64>, Option<usize>),
    I(Vec<i64>, Option<usize>),
}

impl GVal {
    fn width(&self) -> Option<usize> {
        match self {
            GVal::F(_, w) | GVal::I(_, w) => *w,
        }
    }

    fn len(&self) -> usize {
        match self {
            GVal::F(v, w) => v.len() / w.unwrap_or(1),
            GVal::I(v, w) => v.len() / w.unwrap_or(1),
        }
    }

    fn as_f(&self) -> Vec<f64> {
        match self {
            GVal::F(v, _) => v.clone(),
            GVal::I(v, _) => v.iter().map(|&x| x as f64).collect(),
        }
    }

    fn as_i(&self) -> Result<Vec<i64>> {
        match self {
            GVal::I(v, _) => Ok(v.clone()),
            GVal::F(v, _) => Ok(v.iter().map(|&x| x as i64).collect()),
        }
    }

    /// Copy out a contiguous row range (`start..start + len`). Row-wise
    /// ops make this exact: evaluating a node on a row subset yields the
    /// same bits as slicing its full-batch evaluation.
    fn slice_rows(&self, start: usize, len: usize) -> GVal {
        let w = self.width().unwrap_or(1);
        match self {
            GVal::F(v, width) => GVal::F(v[start * w..(start + len) * w].to_vec(), *width),
            GVal::I(v, width) => GVal::I(v[start * w..(start + len) * w].to_vec(), *width),
        }
    }

    fn to_tensor(&self, batch: usize) -> Tensor {
        let shape = match self.width() {
            Some(w) => vec![batch, w],
            None => vec![batch],
        };
        match self {
            // compiled graphs compute in f32 — match that dtype here
            GVal::F(v, _) => Tensor {
                data: TensorData::F32(v.iter().map(|&x| x as f32).collect()),
                shape,
            },
            GVal::I(v, _) => Tensor { data: TensorData::I64(v.clone()), shape },
        }
    }
}

/// Pattern-string → compiled regex, built once per backend load.
///
/// `regex_replace` / `regex_extract` ingress steps used to recompile
/// their pattern on every request (ROADMAP open item); the interpreter
/// now precompiles every pattern its spec mentions — standalone nodes
/// and `fused_ingress` steps alike — at construction. A pattern that
/// fails to compile is simply absent from the cache, so it keeps
/// erroring at request time exactly as before (construction stays
/// infallible).
struct RegexCache(HashMap<String, ops::regex::Regex>);

impl RegexCache {
    fn for_spec(spec: &GraphSpec) -> RegexCache {
        let mut cache = HashMap::new();
        let mut add = |attrs: &Json| {
            if let Some(pattern) = attrs.opt_str("pattern") {
                if !cache.contains_key(pattern) {
                    if let Ok(re) = ops::regex::Regex::new(pattern) {
                        cache.insert(pattern.to_string(), re);
                    }
                }
            }
        };
        for node in &spec.ingress {
            match node.op.as_str() {
                "regex_replace" | "regex_extract" => add(&node.attrs),
                "fused_ingress" => {
                    if let Ok(steps) = node.attrs.req_array("steps") {
                        for s in steps {
                            if matches!(s.opt_str("op"), Some("regex_replace" | "regex_extract")) {
                                add(s);
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        RegexCache(cache)
    }

    /// Cached regex for `pattern`, compiling on miss (bit-identical to
    /// the old per-request path: same engine, same pattern).
    fn get(&self, pattern: &str) -> Result<std::borrow::Cow<'_, ops::regex::Regex>> {
        match self.0.get(pattern) {
            Some(re) => Ok(std::borrow::Cow::Borrowed(re)),
            None => Ok(std::borrow::Cow::Owned(ops::regex::Regex::new(pattern)?)),
        }
    }
}

/// One contiguous row range of a routed batch and the spec outputs
/// (indices into `spec.outputs`) it requests — the interpreter-level
/// shape of a per-variant request group
/// ([`SpecInterpreter::run_routed`]).
#[derive(Debug, Clone)]
pub struct RouteGroup {
    pub outputs: Vec<usize>,
    pub rows: std::ops::Range<usize>,
}

/// Memoised ancestor cones per requested output subset.
///
/// The subsets a server actually routes are known at load time: the
/// full output set (untargeted requests) and each variant's output list
/// — so those keys are **pre-warmed** at construction and their cones
/// fill through a [`OnceLock`](std::sync::OnceLock) on first use.
/// After that, every hot-path lookup is a lock-free read: N pool
/// workers routing concurrent batches ([`crate::serving::Server`] with
/// `BatchConfig::workers > 1`) never serialise on a cache mutex. The
/// cold half keeps the old mutexed memo for ad-hoc subsets (tests,
/// tooling) that no server traffic pattern produces.
struct ConeCache {
    warm: Vec<(Vec<usize>, std::sync::OnceLock<std::sync::Arc<Cone>>)>,
    cold: std::sync::Mutex<HashMap<Vec<usize>, std::sync::Arc<Cone>>>,
}

impl ConeCache {
    /// Pre-warm the routing subsets of `spec`: all outputs, plus one
    /// entry per variant of a merged multi-variant spec.
    fn for_spec(spec: &GraphSpec) -> ConeCache {
        let mut keys: Vec<Vec<usize>> = vec![(0..spec.outputs.len()).collect()];
        for v in spec.variants() {
            let outputs = spec.variant_outputs(v);
            if !keys.contains(&outputs) {
                keys.push(outputs);
            }
        }
        ConeCache {
            warm: keys
                .into_iter()
                .map(|k| (k, std::sync::OnceLock::new()))
                .collect(),
            cold: std::sync::Mutex::new(HashMap::new()),
        }
    }
}

/// Interpreter over one [`GraphSpec`].
pub struct SpecInterpreter {
    spec: GraphSpec,
    /// Every graph-section name the spec actually reads (node inputs +
    /// outputs), computed once so multi-output lane binding does not
    /// clone values for alias names nothing consumes (each lane may be
    /// addressed as `"id.lane"` AND by its bare name).
    referenced: std::collections::HashSet<String>,
    /// Precompiled regexes for every pattern in the ingress section.
    regexes: RegexCache,
    /// Ancestor cones per requested output subset — pre-warmed per
    /// variant, lock-free on the routed serving path.
    cones: ConeCache,
    /// The spec compiled to columnar kernels over a slot-indexed buffer
    /// arena ([`KernelProgram`]) — the hot path for `run` /
    /// `run_routed`. `None` when the spec has a shape the kernel
    /// compiler does not handle (or for [`Self::new_oracle`]); those
    /// specs serve through the original `eval_node` oracle unchanged.
    program: Option<KernelProgram>,
}

impl SpecInterpreter {
    pub fn new(spec: GraphSpec) -> SpecInterpreter {
        let mut interp = SpecInterpreter::new_oracle(spec);
        // best-effort: a compile failure (unknown op, malformed attrs, a
        // regex that does not compile, ...) leaves the oracle path in
        // charge, so construction stays infallible and request-time
        // error behaviour is preserved exactly
        interp.program = KernelProgram::compile(&interp.spec).ok();
        interp
    }

    /// Construct WITHOUT compiling a kernel program: every request runs
    /// through the original `eval_node` path. This is the differential
    /// baseline the kernel path is pinned against (tests, properties,
    /// `benches/kernel_program.rs`).
    pub fn new_oracle(spec: GraphSpec) -> SpecInterpreter {
        let referenced = spec
            .nodes
            .iter()
            .flat_map(|n| n.inputs.iter())
            .chain(spec.outputs.iter())
            .cloned()
            .collect();
        let regexes = RegexCache::for_spec(&spec);
        let cones = ConeCache::for_spec(&spec);
        SpecInterpreter { spec, referenced, regexes, cones, program: None }
    }

    /// Whether this interpreter serves through a compiled kernel program
    /// (false = `eval_node` oracle, by fallback or by `new_oracle`).
    pub fn is_compiled(&self) -> bool {
        self.program.is_some()
    }

    /// Memoised ancestor cone for one requested output subset:
    /// lock-free for the pre-warmed per-variant subsets a routed server
    /// requests, mutexed memo only for ad-hoc subsets.
    fn cone_for(&self, outputs: &[usize]) -> std::sync::Arc<Cone> {
        for (key, slot) in &self.cones.warm {
            if key.as_slice() == outputs {
                return std::sync::Arc::clone(slot.get_or_init(|| {
                    std::sync::Arc::new(self.spec.ancestor_cone_of(outputs))
                }));
            }
        }
        let mut cache = self.cones.cold.lock().unwrap();
        if let Some(c) = cache.get(outputs) {
            return std::sync::Arc::clone(c);
        }
        let cone = std::sync::Arc::new(self.spec.ancestor_cone_of(outputs));
        cache.insert(outputs.to_vec(), std::sync::Arc::clone(&cone));
        cone
    }

    pub fn spec(&self) -> &GraphSpec {
        &self.spec
    }

    /// Run only the ingress section and marshal the graph inputs as
    /// tensors (the serving front-end for the compiled path).
    pub fn run_ingress(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let mut df = df.clone();
        if let Some(p) = &self.program {
            // pre-parsed ingress kernels (same column ops, no per-batch
            // attr lookups)
            p.apply_ingress(&mut df)?;
        } else {
            for node in &self.spec.ingress {
                apply_ingress(node, &mut df, &self.regexes)?;
            }
        }
        let batch = df.num_rows();
        self.spec
            .graph_inputs
            .iter()
            .map(|name| {
                let gv = column_to_gval(df.column(name)?)?;
                // graph inputs declared F32 must arrive as f32 tensors,
                // I64 as i64 — resolve via spec meta
                let (dtype, _) = self.spec.graph_input_meta(name).ok_or_else(|| {
                    KamaeError::Serde(format!("graph input {name} missing meta"))
                })?;
                Ok(match (dtype, gv) {
                    (SpecDType::F32, gv) => gv_to_f32_tensor(gv, batch),
                    (SpecDType::I64, gv) => {
                        let w = gv.width();
                        let data = gv.as_i()?;
                        Tensor {
                            data: TensorData::I64(data),
                            shape: match w {
                                Some(w) => vec![batch, w],
                                None => vec![batch],
                            },
                        }
                    }
                })
            })
            .collect()
    }

    /// Full interpretation: ingress + graph sections. Output order and
    /// dtypes match the compiled artifact exactly.
    ///
    /// Serves through the compiled kernel program when one exists; the
    /// two paths are bit-identical (pinned differentially), so callers
    /// never observe which one ran.
    pub fn run(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        if let Some(p) = &self.program {
            return p.run(df);
        }
        let mut df = df.clone();
        for node in &self.spec.ingress {
            apply_ingress(node, &mut df, &self.regexes)?;
        }
        let batch = df.num_rows();
        let mut env: HashMap<String, GVal> = HashMap::new();
        for name in &self.spec.graph_inputs {
            env.insert(name.clone(), column_to_gval(df.column(name)?)?);
        }
        for node in &self.spec.nodes {
            self.eval_into(node, &mut env)?;
        }
        self.spec
            .outputs
            .iter()
            .map(|o| {
                env.get(o)
                    .map(|g| g.to_tensor(batch))
                    .ok_or_else(|| KamaeError::ColumnNotFound(format!("{o} (spec output)")))
            })
            .collect()
    }

    /// Evaluate one graph node into an env, binding multi-output lanes
    /// under the qualified `id.lane` reference AND the bare lane name
    /// (spec outputs resolve by bare name; rewired consumers use the
    /// qualified one) — but only actually-consumed names get a binding,
    /// so nothing is cloned for unused aliases.
    fn eval_into(&self, node: &SpecNode, env: &mut HashMap<String, GVal>) -> Result<()> {
        if node.lanes.is_empty() {
            let val = eval_node(node, env)?;
            env.insert(node.id.clone(), val);
        } else {
            for (lane_name, val) in eval_multi(node, env)? {
                let qualified = node.lane_ref(&lane_name);
                if self.referenced.contains(&qualified) {
                    if self.referenced.contains(&lane_name) {
                        env.insert(qualified, val.clone());
                        env.insert(lane_name, val);
                    } else {
                        env.insert(qualified, val);
                    }
                } else {
                    env.insert(lane_name, val);
                }
            }
        }
        Ok(())
    }

    /// Variant-routed interpretation: one mixed batch whose contiguous
    /// row groups each request only a *subset* of the spec's outputs
    /// (one serving variant per group, in the batcher's shape). Returns
    /// the requested output tensors per group, in the group's
    /// `outputs` order.
    ///
    /// Evaluation walks only the union of the groups' ancestor cones,
    /// at **row granularity**:
    ///
    /// * a node needed by two or more groups (the shared preprocessing
    ///   prefix of a merged multi-variant spec) evaluates ONCE over the
    ///   full batch — the shared-prefix env is reused across every
    ///   variant in the batch,
    /// * a node needed by exactly one group evaluates over that group's
    ///   rows only — variant-exclusive work never runs on another
    ///   variant's rows,
    /// * a node needed by no group never runs at all.
    ///
    /// Every op in the vocabulary is row-wise, so restricting a node to
    /// a row subset is bit-identical to slicing its full-batch
    /// evaluation — `run_routed` output equals the matching slices of
    /// [`Self::run`] bit for bit (pinned by the routing property
    /// tests). Shared values consumed by group-scoped nodes are sliced
    /// once per group and memoised in the group env.
    pub fn run_routed(&self, df: &DataFrame, groups: &[RouteGroup]) -> Result<Vec<Vec<Tensor>>> {
        let spec = &self.spec;
        // validate the group cover: contiguous, in order, non-empty
        let mut expect_start = 0usize;
        for g in groups {
            if g.rows.start != expect_start || g.rows.is_empty() {
                return Err(KamaeError::InvalidConfig(format!(
                    "route groups must cover the batch contiguously: group at \
                     {}..{} after row {expect_start}",
                    g.rows.start, g.rows.end
                )));
            }
            expect_start = g.rows.end;
        }
        if expect_start != df.num_rows() {
            return Err(KamaeError::InvalidConfig(format!(
                "route groups cover {expect_start} of {} batch rows",
                df.num_rows()
            )));
        }
        // group-count cap for the per-node bitmasks; a server routes
        // between a handful of variants, so this is never the fallback
        // in practice
        if groups.len() > 64 {
            return Err(KamaeError::InvalidConfig(format!(
                "too many route groups ({} > 64)",
                groups.len()
            )));
        }

        // per-node / per-input needed-by bitmasks over the groups
        let cones: Vec<std::sync::Arc<Cone>> =
            groups.iter().map(|g| self.cone_for(&g.outputs)).collect();
        let mut ingress_masks = vec![0u64; spec.ingress.len()];
        let mut input_masks = vec![0u64; spec.graph_inputs.len()];
        let mut node_masks = vec![0u64; spec.nodes.len()];
        for (gi, cone) in cones.iter().enumerate() {
            let bit = 1u64 << gi;
            for (masks, members) in [
                (&mut ingress_masks, &cone.ingress),
                (&mut input_masks, &cone.graph_inputs),
                (&mut node_masks, &cone.nodes),
            ] {
                for (i, needed) in members.iter().enumerate() {
                    if *needed {
                        masks[i] |= bit;
                    }
                }
            }
        }

        // compiled hot path: the kernel program executes the same
        // per-cone sub-program shape (shared nodes once over the full
        // batch, exclusive nodes on their group's rows) over slot
        // arenas instead of name envs — bit-identical by construction
        if let Some(p) = &self.program {
            return p.run_routed(df, groups, &ingress_masks, &input_masks, &node_masks);
        }

        // ---- ingress, shared scope: nodes ≥2 groups need run over the
        // full batch first (their inputs are at least as shared — a
        // consumer's cone membership implies its producers'), then each
        // group's exclusive ingress nodes run over that group's slice
        let mut full_df = df.clone();
        for (i, node) in spec.ingress.iter().enumerate() {
            if ingress_masks[i].count_ones() >= 2 {
                apply_ingress(node, &mut full_df, &self.regexes)?;
            }
        }
        let mut group_dfs: Vec<Option<DataFrame>> = vec![None; groups.len()];
        for (gi, g) in groups.iter().enumerate() {
            let mut gdf: Option<DataFrame> = None;
            for (i, node) in spec.ingress.iter().enumerate() {
                if ingress_masks[i] == 1 << gi {
                    let gdf = gdf.get_or_insert_with(|| {
                        full_df.slice(g.rows.start, g.rows.len())
                    });
                    apply_ingress(node, gdf, &self.regexes)?;
                }
            }
            group_dfs[gi] = gdf;
        }

        // ---- graph inputs: marshal shared ones from the full batch,
        // group-exclusive ones from the group's rows, skip the rest
        let mut env_full: HashMap<String, GVal> = HashMap::new();
        let mut env_groups: Vec<HashMap<String, GVal>> =
            (0..groups.len()).map(|_| HashMap::new()).collect();
        for (i, name) in spec.graph_inputs.iter().enumerate() {
            let m = input_masks[i];
            if m.count_ones() >= 2 {
                env_full.insert(name.clone(), column_to_gval(full_df.column(name)?)?);
            } else if m != 0 {
                let gi = m.trailing_zeros() as usize;
                let g = &groups[gi];
                let col = match &group_dfs[gi] {
                    Some(gdf) => column_to_gval(gdf.column(name)?)?,
                    None => column_to_gval(
                        full_df.slice(g.rows.start, g.rows.len()).column(name)?,
                    )?,
                };
                env_groups[gi].insert(name.clone(), col);
            }
        }

        // ---- graph nodes at row granularity
        for (i, node) in spec.nodes.iter().enumerate() {
            let m = node_masks[i];
            if m == 0 {
                continue;
            }
            if m.count_ones() >= 2 {
                self.eval_into(node, &mut env_full)?;
            } else {
                let gi = m.trailing_zeros() as usize;
                let g = &groups[gi];
                // group-scoped inputs come from the group env; shared
                // inputs are sliced to the group's rows once and
                // memoised there
                for input in &node.inputs {
                    if !env_groups[gi].contains_key(input) {
                        if let Some(v) = env_full.get(input) {
                            env_groups[gi].insert(
                                input.clone(),
                                v.slice_rows(g.rows.start, g.rows.len()),
                            );
                        }
                    }
                }
                self.eval_into(node, &mut env_groups[gi])?;
            }
        }

        // ---- collect each group's requested outputs
        groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                g.outputs
                    .iter()
                    .map(|&oi| {
                        let name = spec.outputs.get(oi).ok_or_else(|| {
                            KamaeError::InvalidConfig(format!(
                                "route group requests output {oi} of {}",
                                spec.outputs.len()
                            ))
                        })?;
                        if let Some(v) = env_groups[gi].get(name) {
                            return Ok(v.to_tensor(g.rows.len()));
                        }
                        env_full
                            .get(name)
                            .map(|v| {
                                v.slice_rows(g.rows.start, g.rows.len())
                                    .to_tensor(g.rows.len())
                            })
                            .ok_or_else(|| {
                                KamaeError::ColumnNotFound(format!("{name} (routed spec output)"))
                            })
                    })
                    .collect()
            })
            .collect()
    }

    /// Time every spec node's evaluation over one batch — the
    /// measurement half of the cost-model calibration harness
    /// (`kamae optimize --calibrate`, [`crate::optim::calibrate`]).
    ///
    /// Each node is evaluated `repeats` times in spec order and its
    /// mean wall time recorded. Re-evaluation is idempotent: a node
    /// only ever writes its own output column / env binding, never its
    /// inputs, so every repeat sees identical operands. The timing
    /// deliberately includes the per-node bookkeeping (column
    /// materialisation, env round trip) — that is exactly the overhead
    /// the registry cost model charges as `NODE_OVERHEAD`, so measured
    /// and estimated costs describe the same quantity.
    ///
    /// Profiling deliberately stays on the `eval_node` oracle path even
    /// when a kernel program is compiled: the cost model describes (and
    /// is calibrated against) per-node env evaluation, and the kernel
    /// program has no per-node seam to time in isolation.
    pub fn profile(&self, df: &DataFrame, repeats: usize) -> Result<Vec<NodeTiming>> {
        let repeats = repeats.max(1);
        let rows = df.num_rows();
        let mut out = Vec::with_capacity(self.spec.ingress.len() + self.spec.nodes.len());
        let mut df = df.clone();
        for node in &self.spec.ingress {
            let t0 = std::time::Instant::now();
            for _ in 0..repeats {
                apply_ingress(node, &mut df, &self.regexes)?;
            }
            out.push(NodeTiming {
                id: node.id.clone(),
                op: node.op.clone(),
                ingress: true,
                mean_ns: t0.elapsed().as_nanos() as f64 / repeats as f64,
                rows,
            });
        }
        let mut env: HashMap<String, GVal> = HashMap::new();
        for name in &self.spec.graph_inputs {
            env.insert(name.clone(), column_to_gval(df.column(name)?)?);
        }
        for node in &self.spec.nodes {
            let t0 = std::time::Instant::now();
            for _ in 0..repeats {
                self.eval_into(node, &mut env)?;
            }
            out.push(NodeTiming {
                id: node.id.clone(),
                op: node.op.clone(),
                ingress: false,
                mean_ns: t0.elapsed().as_nanos() as f64 / repeats as f64,
                rows,
            });
        }
        Ok(out)
    }
}

/// One timed spec node from [`SpecInterpreter::profile`].
#[derive(Debug, Clone)]
pub struct NodeTiming {
    /// Node id (the output column / env binding it produces).
    pub id: String,
    /// Op name (the registry key the cost model estimates under).
    pub op: String,
    /// True for ingress-section nodes, false for graph-section nodes.
    pub ingress: bool,
    /// Mean wall time of ONE evaluation over the profiled batch, ns.
    pub mean_ns: f64,
    /// Rows in the profiled batch.
    pub rows: usize,
}

fn gv_to_f32_tensor(gv: GVal, batch: usize) -> Tensor {
    let w = gv.width();
    let data: Vec<f32> = gv.as_f().iter().map(|&x| x as f32).collect();
    Tensor {
        data: TensorData::F32(data),
        shape: match w {
            Some(w) => vec![batch, w],
            None => vec![batch],
        },
    }
}

// ---------------------------------------------------------------------------
// ingress section — DataFrame column ops

fn apply_ingress(node: &SpecNode, df: &mut DataFrame, regexes: &RegexCache) -> Result<()> {
    let cols: Vec<&Column> = node
        .inputs
        .iter()
        .map(|n| df.column(n))
        .collect::<Result<_>>()?;
    let out = ingress_op_column(&node.op, &node.attrs, &cols, regexes)?;
    df.set_column(node.id.clone(), out)
}

/// Evaluate one ingress op over already-resolved input columns. Shared
/// by [`apply_ingress`] (columns from the request DataFrame) and the
/// fused-chain replay (columns are in-flight intermediates that never
/// touch the DataFrame). Regex steps resolve through the interpreter's
/// per-spec precompiled cache instead of recompiling per request.
fn ingress_op_column(op: &str, a: &Json, cols: &[&Column], regexes: &RegexCache) -> Result<Column> {
    let input = |i: usize| -> Result<&Column> {
        cols.get(i).copied().ok_or_else(|| {
            KamaeError::InvalidConfig(format!("ingress op {op}: missing input {i}"))
        })
    };
    Ok(match op {
        "hash64" => ops::hash::hash64_column(input(0)?)?,
        "case" => {
            let mode = match a.req_str("mode")? {
                "upper" => ops::string_ops::CaseMode::Upper,
                "lower" => ops::string_ops::CaseMode::Lower,
                _ => ops::string_ops::CaseMode::Title,
            };
            ops::string_ops::change_case(input(0)?, mode)?
        }
        "trim" => ops::string_ops::trim(input(0)?)?,
        "substring" => ops::string_ops::substring(
            input(0)?,
            a.req_i64("start")? as usize,
            a.req_i64("len")? as usize,
        )?,
        "replace" => ops::string_ops::replace_literal(input(0)?, a.req_str("from")?, a.req_str("to")?)?,
        "regex_replace" => {
            let re = regexes.get(a.req_str("pattern")?)?;
            ops::regex::regex_replace(input(0)?, &re, a.req_str("rep")?)?
        }
        "regex_extract" => {
            let re = regexes.get(a.req_str("pattern")?)?;
            ops::regex::regex_extract(input(0)?, &re, a.req_i64("group")? as usize)?
        }
        "concat" => ops::string_ops::concat_cols(cols, a.req_str("separator")?)?,
        "split_pad" => {
            let split = ops::string_ops::split(input(0)?, a.req_str("separator")?)?;
            ops::string_ops::pad_list(&split, a.req_i64("list_length")? as usize, a.req_str("default")?)?
        }
        "join" => {
            let l = input(0)?.as_list_str()?;
            let sep = a.req_str("separator")?;
            Column::from_str(l.rows().map(|r| r.join(sep)).collect::<Vec<String>>())
        }
        "string_match" => {
            let mode = match a.req_str("mode")? {
                "starts_with" => ops::string_ops::MatchMode::StartsWith,
                "ends_with" => ops::string_ops::MatchMode::EndsWith,
                _ => ops::string_ops::MatchMode::Contains,
            };
            ops::string_ops::string_match(input(0)?, a.req_str("needle")?, mode)?
        }
        "str_len" => ops::string_ops::str_len(input(0)?)?,
        "date_to_days" => ops::date::date_to_days(input(0)?)?,
        "timestamp_to_seconds" => ops::date::timestamp_to_seconds(input(0)?)?,
        "element_at" => ops::array::element_at(input(0)?, a.req_i64("index")?)?,
        "slice_list" => ops::array::slice_list(
            input(0)?,
            a.req_i64("start")? as usize,
            a.req_i64("len")? as usize,
        )?,
        "pad_list" => ops::string_ops::pad_list(
            input(0)?,
            a.req_i64("len")? as usize,
            a.req_str("default")?,
        )?,
        "to_string" => ops::cast::cast(input(0)?, &DType::Str)?,
        "parse_number" => ops::cast::cast(input(0)?, &DType::F64)?,
        "fused_ingress" => run_fused_ingress(a, input(0)?, regexes)?,
        other => {
            return Err(KamaeError::Unsupported(format!("ingress op: {other}")))
        }
    })
}

// ---------------------------------------------------------------------------
// fused ingress chains (optim::passes::IngressFuse)

/// One per-value step of the fused string fast path (shared with the
/// kernel-program ingress compiler, which parses the chain once at
/// backend load instead of per batch).
pub(super) enum StrStep {
    Trim,
    Case(ops::string_ops::CaseMode),
    Replace(String, String),
    Substring(usize, usize),
}

/// Execute a fused ingress chain. The common shape — per-value string
/// ops optionally terminated by `hash64` — runs as ONE walk over the
/// column (no intermediate column materialisation at all); anything
/// else replays the recorded steps with the exact column kernels the
/// separate nodes used. Both paths are bit-identical to the unfused
/// chain by construction.
fn run_fused_ingress(a: &Json, input: &Column, regexes: &RegexCache) -> Result<Column> {
    let steps = a.req_array("steps")?;
    if let Some(out) = fused_string_walk(steps, input)? {
        return Ok(out);
    }
    let mut col = input.clone();
    for s in steps {
        col = ingress_op_column(s.req_str("op")?, s, &[&col], regexes)?;
    }
    Ok(col)
}

/// Single-walk fast path; `None` when the chain or input shape doesn't
/// qualify (the caller falls back to step replay).
fn fused_string_walk(steps: &[Json], input: &Column) -> Result<Option<Column>> {
    Ok(match parse_fused_chain(steps)? {
        Some((chain, hash_tail)) => run_fused_walk(&chain, hash_tail, input),
        None => None,
    })
}

/// Parse a fused-ingress step list into the per-value walk chain, once.
/// `None` when the chain doesn't qualify for the single-walk path
/// (replay handles it). Shared with the kernel-program compiler, which
/// hoists this parse to backend-load time.
pub(super) fn parse_fused_chain(steps: &[Json]) -> Result<Option<(Vec<StrStep>, bool)>> {
    use ops::string_ops as so;
    let mut chain: Vec<StrStep> = Vec::new();
    let mut hash_tail = false;
    for (i, s) in steps.iter().enumerate() {
        match s.req_str("op")? {
            "trim" => chain.push(StrStep::Trim),
            "case" => {
                let mode = match s.req_str("mode")? {
                    "upper" => so::CaseMode::Upper,
                    "lower" => so::CaseMode::Lower,
                    _ => so::CaseMode::Title,
                };
                chain.push(StrStep::Case(mode));
            }
            "replace" => chain.push(StrStep::Replace(
                s.req_str("from")?.to_string(),
                s.req_str("to")?.to_string(),
            )),
            "substring" => chain.push(StrStep::Substring(
                s.req_i64("start")? as usize,
                s.req_i64("len")? as usize,
            )),
            "hash64" if i == steps.len() - 1 => hash_tail = true,
            _ => return Ok(None),
        }
    }
    Ok(Some((chain, hash_tail)))
}

/// Apply a parsed fused chain as one walk over the column. `None` when
/// the input column shape doesn't qualify (caller replays step by step).
pub(super) fn run_fused_walk(chain: &[StrStep], hash_tail: bool, input: &Column) -> Option<Column> {
    use crate::dataframe::ListColumn;
    use ops::string_ops as so;
    let apply = |s: &str| -> String {
        let mut cur = s.to_string();
        for step in chain {
            cur = match step {
                StrStep::Trim => cur.trim().to_string(),
                StrStep::Case(mode) => so::case_value(&cur, *mode),
                StrStep::Replace(from, to) => cur.replace(from.as_str(), to.as_str()),
                StrStep::Substring(start, len) => so::substring_value(&cur, *start, *len),
            };
        }
        cur
    };
    match input {
        Column::Str(v, nulls) => Some(if hash_tail {
            Column::I64(
                v.iter().map(|s| ops::hash::fnv1a64(&apply(s))).collect(),
                nulls.clone(),
            )
        } else {
            Column::Str(v.iter().map(|s| apply(s.as_str())).collect(), nulls.clone())
        }),
        Column::ListStr(l) => Some(if hash_tail {
            Column::ListI64(ListColumn {
                values: l.values.iter().map(|s| ops::hash::fnv1a64(&apply(s))).collect(),
                offsets: l.offsets.clone(),
            })
        } else {
            Column::ListStr(ListColumn {
                values: l.values.iter().map(|s| apply(s.as_str())).collect(),
                offsets: l.offsets.clone(),
            })
        }),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// graph section — flat-buffer ops (the semantics model.py compiles)

fn column_to_gval(col: &Column) -> Result<GVal> {
    Ok(match col {
        Column::Bool(v, _) => GVal::I(v.iter().map(|&b| b as i64).collect(), None),
        Column::I32(v, _) => GVal::I(v.iter().map(|&x| x as i64).collect(), None),
        Column::I64(v, _) => GVal::I(v.clone(), None),
        Column::F32(v, _) => GVal::F(v.iter().map(|&x| x as f64).collect(), None),
        Column::F64(v, _) => GVal::F(v.clone(), None),
        Column::ListBool(l) => {
            let w = fixed_width(&l.offsets, "bool list")?;
            GVal::I(l.values.iter().map(|&b| b as i64).collect(), Some(w))
        }
        Column::ListI32(l) => {
            let w = fixed_width(&l.offsets, "int32 list")?;
            GVal::I(l.values.iter().map(|&x| x as i64).collect(), Some(w))
        }
        Column::ListI64(l) => {
            let w = fixed_width(&l.offsets, "int64 list")?;
            GVal::I(l.values.clone(), Some(w))
        }
        Column::ListF32(l) => {
            let w = fixed_width(&l.offsets, "float32 list")?;
            GVal::F(l.values.iter().map(|&x| x as f64).collect(), Some(w))
        }
        Column::ListF64(l) => {
            let w = fixed_width(&l.offsets, "float64 list")?;
            GVal::F(l.values.clone(), Some(w))
        }
        Column::Str(..) | Column::ListStr(_) => {
            return Err(KamaeError::Unsupported(
                "string column crossing into graph section (missing hash64?)".into(),
            ))
        }
    })
}

pub(super) fn fixed_width(offsets: &[u32], what: &str) -> Result<usize> {
    if offsets.len() < 2 {
        return Ok(0);
    }
    let w = (offsets[1] - offsets[0]) as usize;
    for win in offsets.windows(2) {
        if (win[1] - win[0]) as usize != w {
            return Err(KamaeError::InvalidConfig(format!(
                "ragged {what} cannot enter the graph section"
            )));
        }
    }
    Ok(w)
}

pub(super) fn attr_f64_array(a: &Json, key: &str) -> Result<Vec<f64>> {
    a.req_array(key)?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| KamaeError::Serde(format!("{key} entry"))))
        .collect()
}

pub(super) fn attr_i64_array(a: &Json, key: &str) -> Result<Vec<i64>> {
    a.req_array(key)?
        .iter()
        .map(|v| v.as_i64().ok_or_else(|| KamaeError::Serde(format!("{key} entry"))))
        .collect()
}

fn eval_node(node: &SpecNode, env: &HashMap<String, GVal>) -> Result<GVal> {
    use ops::math::UnaryOp;
    let a = &node.attrs;
    let arg = |i: usize| -> Result<&GVal> {
        env.get(&node.inputs[i]).ok_or_else(|| {
            KamaeError::ColumnNotFound(format!("{} (graph value)", node.inputs[i]))
        })
    };

    // unary float ops share a table
    let unary_op: Option<UnaryOp> = match node.op.as_str() {
        "log" => Some(match a.opt_f64("base") {
            Some(b) => UnaryOp::Log { base: Some(b) },
            None => UnaryOp::Log { base: None },
        }),
        "log1p" => Some(UnaryOp::Log1p),
        "exp" => Some(UnaryOp::Exp),
        "sqrt" => Some(UnaryOp::Sqrt),
        "abs" => Some(UnaryOp::Abs),
        "neg" => Some(UnaryOp::Neg),
        "reciprocal" => Some(UnaryOp::Reciprocal),
        "round" => Some(UnaryOp::Round),
        "floor" => Some(UnaryOp::Floor),
        "ceil" => Some(UnaryOp::Ceil),
        "sin" => Some(UnaryOp::Sin),
        "cos" => Some(UnaryOp::Cos),
        "tanh" => Some(UnaryOp::Tanh),
        "sigmoid" => Some(UnaryOp::Sigmoid),
        "clip" => Some(UnaryOp::Clip { min: a.opt_f64("min"), max: a.opt_f64("max") }),
        "pow_scalar" => Some(UnaryOp::PowScalar { p: a.req_f64("p")? }),
        "add_scalar" => Some(UnaryOp::AddScalar { c: a.req_f64("c")? }),
        "sub_scalar" => Some(UnaryOp::SubScalar { c: a.req_f64("c")? }),
        "mul_scalar" => Some(UnaryOp::MulScalar { c: a.req_f64("c")? }),
        "div_scalar" => Some(UnaryOp::DivScalar { c: a.req_f64("c")? }),
        "scale_shift" => Some(UnaryOp::ScaleShift {
            scale: a.req_f64("scale")?,
            shift: a.req_f64("shift")?,
        }),
        _ => None,
    };
    if let Some(op) = unary_op {
        let x = arg(0)?;
        // match compiled-graph f32 intermediate rounding
        let data = x
            .as_f()
            .iter()
            .map(|&v| op.apply(v as f32 as f64) as f32 as f64)
            .collect();
        return Ok(GVal::F(data, x.width()));
    }

    // fused scalar-affine chain (produced by optim::passes::AffineFuse).
    // Replays the original per-node steps with the same f32 rounding, so
    // fused and unfused graphs agree bit-for-bit.
    if node.op == "affine" {
        let x = arg(0)?;
        let steps: Vec<UnaryOp> = a
            .req_array("steps")?
            .iter()
            .map(|s| {
                Ok(match s.req_str("op")? {
                    "add_scalar" => UnaryOp::AddScalar { c: s.req_f64("c")? },
                    "sub_scalar" => UnaryOp::SubScalar { c: s.req_f64("c")? },
                    "mul_scalar" => UnaryOp::MulScalar { c: s.req_f64("c")? },
                    "div_scalar" => UnaryOp::DivScalar { c: s.req_f64("c")? },
                    "scale_shift" => UnaryOp::ScaleShift {
                        scale: s.req_f64("scale")?,
                        shift: s.req_f64("shift")?,
                    },
                    other => {
                        return Err(KamaeError::Unsupported(format!("affine step: {other}")))
                    }
                })
            })
            .collect::<Result<_>>()?;
        let data = x
            .as_f()
            .iter()
            .map(|&v| {
                let mut y = v;
                for op in &steps {
                    y = op.apply(y as f32 as f64) as f32 as f64;
                }
                y
            })
            .collect();
        return Ok(GVal::F(data, x.width()));
    }

    // binary float ops
    if let Ok(op) = ops::math::BinOp::from_name(&node.op) {
        let (x, y) = (arg(0)?, arg(1)?);
        let (xv, yv) = (x.as_f(), y.as_f());
        let w = x.width().or(y.width());
        let data: Vec<f64> = match (x.width(), y.width()) {
            (Some(wx), None) => xv
                .iter()
                .enumerate()
                .map(|(i, &p)| op.apply(p as f32 as f64, yv[i / wx] as f32 as f64) as f32 as f64)
                .collect(),
            (None, Some(wy)) => yv
                .iter()
                .enumerate()
                .map(|(i, &q)| op.apply(xv[i / wy] as f32 as f64, q as f32 as f64) as f32 as f64)
                .collect(),
            _ => {
                if xv.len() != yv.len() {
                    return Err(KamaeError::LengthMismatch {
                        left: xv.len(),
                        right: yv.len(),
                        context: format!("graph op {}", node.op),
                    });
                }
                xv.iter()
                    .zip(yv.iter())
                    .map(|(&p, &q)| op.apply(p as f32 as f64, q as f32 as f64) as f32 as f64)
                    .collect()
            }
        };
        return Ok(GVal::F(data, w));
    }

    Ok(match node.op.as_str() {
        "identity" => arg(0)?.clone(),
        "to_f32" => GVal::F(arg(0)?.as_f(), arg(0)?.width()),
        "to_i64" => GVal::I(arg(0)?.as_i()?, arg(0)?.width()),
        "bucketize" => {
            let splits = attr_f64_array(a, "splits")?;
            let x = arg(0)?;
            GVal::I(
                x.as_f()
                    .iter()
                    .map(|&v| splits.partition_point(|&s| s <= v) as i64)
                    .collect(),
                x.width(),
            )
        }
        "columns_agg" => {
            let n = node.inputs.len() as f64;
            let agg = a.req_str("agg")?;
            let cols: Vec<Vec<f64>> = (0..node.inputs.len())
                .map(|i| Ok(arg(i)?.as_f()))
                .collect::<Result<_>>()?;
            let rows = cols[0].len();
            let data = (0..rows)
                .map(|r| {
                    let mut acc = cols[0][r];
                    for c in cols.iter().skip(1) {
                        acc = match agg {
                            "min" => acc.min(c[r]),
                            "max" => acc.max(c[r]),
                            _ => acc + c[r],
                        };
                    }
                    if agg == "mean" {
                        acc / n
                    } else {
                        acc
                    }
                })
                .collect();
            GVal::F(data, None)
        }
        "date_part" => {
            let part = ops::date::DatePart::from_name(a.req_str("part")?)?;
            let x = arg(0)?.as_i()?;
            GVal::I(x.iter().map(|&d| part.extract(d)).collect(), arg(0)?.width())
        }
        "sub_i64" => {
            let (x, y) = (arg(0)?.as_i()?, arg(1)?.as_i()?);
            GVal::I(x.iter().zip(y.iter()).map(|(&p, &q)| p - q).collect(), arg(0)?.width())
        }
        "add_scalar_i64" => {
            let c = a.req_i64("c")?;
            GVal::I(arg(0)?.as_i()?.iter().map(|&x| x + c).collect(), arg(0)?.width())
        }
        "floordiv_scalar_i64" => {
            let c = a.req_i64("c")?;
            GVal::I(
                arg(0)?.as_i()?.iter().map(|&x| x.div_euclid(c)).collect(),
                arg(0)?.width(),
            )
        }
        "compare" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let (x, y) = (arg(0)?.as_f(), arg(1)?.as_f());
            GVal::I(
                x.iter()
                    .zip(y.iter())
                    .map(|(&p, &q)| op.apply_f64(p as f32 as f64, q as f32 as f64) as i64)
                    .collect(),
                arg(0)?.width(),
            )
        }
        "compare_scalar" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let c = a.req_f64("value")?;
            GVal::I(
                arg(0)?
                    .as_f()
                    .iter()
                    .map(|&p| op.apply_f64(p as f32 as f64, c as f32 as f64) as i64)
                    .collect(),
                arg(0)?.width(),
            )
        }
        "eq_hash" => {
            let h = a.req_i64("value_hash")?;
            GVal::I(
                arg(0)?.as_i()?.iter().map(|&x| (x == h) as i64).collect(),
                arg(0)?.width(),
            )
        }
        "bool_op" => {
            let op = a.req_str("op")?;
            let (x, y) = (arg(0)?.as_i()?, arg(1)?.as_i()?);
            GVal::I(
                x.iter()
                    .zip(y.iter())
                    .map(|(&p, &q)| {
                        let (p, q) = (p != 0, q != 0);
                        (match op {
                            "and" => p && q,
                            "or" => p || q,
                            _ => p ^ q,
                        }) as i64
                    })
                    .collect(),
                arg(0)?.width(),
            )
        }
        "not" => GVal::I(
            arg(0)?.as_i()?.iter().map(|&x| (x == 0) as i64).collect(),
            arg(0)?.width(),
        ),
        "select" => {
            let c = arg(0)?.as_i()?;
            let (x, y) = (arg(1)?.as_f(), arg(2)?.as_f());
            GVal::F(
                c.iter()
                    .enumerate()
                    .map(|(i, &k)| if k != 0 { x[i] } else { y[i] })
                    .collect(),
                arg(1)?.width(),
            )
        }
        // fused select(compare_scalar(x), a, b) — optim::passes::SelectCmpFuse.
        // The predicate replays compare_scalar's exact arithmetic (f32-rounded
        // operands compared in f64), the branches copy raw values like select.
        "select_cmp" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let value = a.req_f64("value")?;
            let c = arg(0)?.as_f();
            let (x, y) = (arg(1)?.as_f(), arg(2)?.as_f());
            GVal::F(
                c.iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        if op.apply_f64(v as f32 as f64, value as f32 as f64) {
                            x[i]
                        } else {
                            y[i]
                        }
                    })
                    .collect(),
                arg(1)?.width(),
            )
        }
        // fused compare_scalar(bucketize(x)) — optim::passes::BucketizeMerge.
        // One sorted-splits binary search per value (raw f64, exactly like
        // bucketize), then the threshold compare of the bucket index with
        // compare_scalar's f32 rounding discipline.
        "multi_bucketize" => {
            let splits = attr_f64_array(a, "splits")?;
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let value = a.req_f64("value")?;
            let x = arg(0)?;
            GVal::I(
                x.as_f()
                    .iter()
                    .map(|&v| {
                        let bucket = splits.partition_point(|&s| s <= v) as i64;
                        op.apply_f64(bucket as f64 as f32 as f64, value as f32 as f64) as i64
                    })
                    .collect(),
                x.width(),
            )
        }
        "is_nan" => GVal::I(
            arg(0)?.as_f().iter().map(|&x| x.is_nan() as i64).collect(),
            arg(0)?.width(),
        ),
        "assemble" => {
            let cols: Vec<Vec<f64>> = (0..node.inputs.len())
                .map(|i| Ok(arg(i)?.as_f()))
                .collect::<Result<_>>()?;
            let rows = cols[0].len();
            let w = cols.len();
            let mut data = Vec::with_capacity(rows * w);
            for r in 0..rows {
                for c in &cols {
                    data.push(c[r]);
                }
            }
            GVal::F(data, Some(w))
        }
        "vector_at" => {
            let idx = a.req_i64("index")? as usize;
            let x = arg(0)?;
            let w = x.width().ok_or_else(|| {
                KamaeError::InvalidConfig("vector_at on scalar".into())
            })?;
            GVal::F(x.as_f().chunks(w).map(|row| row[idx]).collect(), None)
        }
        "list_sum" | "list_mean" | "list_min" | "list_max" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("list agg on scalar".into()))?;
            let data = x
                .as_f()
                .chunks(w)
                .map(|row| match node.op.as_str() {
                    "list_sum" => row.iter().sum(),
                    "list_mean" => row.iter().sum::<f64>() / w as f64,
                    "list_min" => row.iter().copied().fold(f64::INFINITY, f64::min),
                    _ => row.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                })
                .collect();
            GVal::F(data, None)
        }
        "list_len" => {
            let x = arg(0)?;
            let w = x.width().unwrap_or(1) as i64;
            GVal::I(vec![w; x.len()], None)
        }
        "element_at" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("element_at on scalar".into()))?;
            let idx = a.req_i64("index")?;
            let j = if idx < 0 { w as i64 + idx } else { idx } as usize;
            match x {
                GVal::F(v, _) => GVal::F(v.chunks(w).map(|row| row[j]).collect(), None),
                GVal::I(v, _) => GVal::I(v.chunks(w).map(|row| row[j]).collect(), None),
            }
        }
        "slice_list" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("slice_list on scalar".into()))?;
            let start = a.req_i64("start")? as usize;
            let len = a.req_i64("len")? as usize;
            let s = start.min(w);
            let e = (start + len).min(w);
            match x {
                GVal::F(v, _) => GVal::F(
                    v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                    Some(e - s),
                ),
                GVal::I(v, _) => GVal::I(
                    v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                    Some(e - s),
                ),
            }
        }
        "hash_bucket" => {
            let bins = a.req_i64("num_bins")?;
            let x = arg(0)?;
            GVal::I(
                x.as_i()?.iter().map(|&h| ops::hash::bucket(h, 0, bins)).collect(),
                x.width(),
            )
        }
        "bloom_encode" => {
            let k = a.req_i64("num_hashes")? as usize;
            let bins = a.req_i64("num_bins")?;
            let x = arg(0)?.as_i()?;
            let mut data = Vec::with_capacity(x.len() * k);
            for &h in &x {
                for j in 0..k {
                    data.push(j as i64 * bins + ops::hash::bucket(h, j, bins));
                }
            }
            GVal::I(data, Some(k))
        }
        "vocab_lookup" => {
            let hashes = attr_i64_array(a, "vocab_hashes")?;
            let ranks = attr_i64_array(a, "vocab_ranks")?;
            let num_oov = a.req_i64("num_oov")?;
            let base = a.req_i64("base")?;
            let mask_hash = a.opt_i64("mask_hash");
            let x = arg(0)?;
            let data = x
                .as_i()?
                .iter()
                .map(|&h| {
                    if Some(h) == mask_hash {
                        return 0;
                    }
                    match hashes.binary_search(&h) {
                        Ok(i) => base + num_oov + ranks[i],
                        Err(_) => base + ops::hash::bucket(h, 0, num_oov),
                    }
                })
                .collect();
            GVal::I(data, x.width())
        }
        "one_hot" => {
            let hashes = attr_i64_array(a, "vocab_hashes")?;
            let ranks = attr_i64_array(a, "vocab_ranks")?;
            let num_oov = a.req_i64("num_oov")? as usize;
            let drop_unseen = a.opt_bool("drop_unseen").unwrap_or(false);
            let depth = if drop_unseen {
                hashes.len()
            } else {
                num_oov + hashes.len()
            };
            let x = arg(0)?.as_i()?;
            let mut data = vec![0.0f64; x.len() * depth];
            for (i, &h) in x.iter().enumerate() {
                let hot = match hashes.binary_search(&h) {
                    Ok(j) => Some(if drop_unseen {
                        ranks[j] as usize
                    } else {
                        num_oov + ranks[j] as usize
                    }),
                    Err(_) => {
                        if drop_unseen {
                            None
                        } else {
                            Some(ops::hash::bucket(h, 0, num_oov as i64) as usize)
                        }
                    }
                };
                if let Some(hpos) = hot {
                    data[i * depth + hpos] = 1.0;
                }
            }
            GVal::F(data, Some(depth))
        }
        "scale_vec" => {
            let scale = attr_f64_array(a, "scale")?;
            let shift = attr_f64_array(a, "shift")?;
            let x = arg(0)?;
            let w = x.width().unwrap_or(1);
            if scale.len() != w {
                return Err(KamaeError::LengthMismatch {
                    left: scale.len(),
                    right: w,
                    context: "scale_vec width".into(),
                });
            }
            let data = x
                .as_f()
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    ((v as f32) * (scale[i % w] as f32) + (shift[i % w] as f32)) as f64
                })
                .collect();
            GVal::F(data, x.width())
        }
        "impute" => {
            let fill = a.req_f64("fill")?;
            let mask = a.opt_f64("mask_value");
            let x = arg(0)?;
            let data = x
                .as_f()
                .iter()
                .map(|&v| {
                    if v.is_nan() || Some(v) == mask {
                        fill as f32 as f64
                    } else {
                        v as f32 as f64
                    }
                })
                .collect();
            GVal::F(data, x.width())
        }
        "cosine_similarity" => {
            let (x, y) = (arg(0)?, arg(1)?);
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("cosine on scalar".into()))?;
            let (xv, yv) = (x.as_f(), y.as_f());
            let data = xv
                .chunks(w)
                .zip(yv.chunks(w))
                .map(|(a, b)| {
                    let dot: f64 = a.iter().zip(b.iter()).map(|(p, q)| (*p as f32 * *q as f32) as f64).sum();
                    let nx = a.iter().map(|p| (*p as f32 * *p as f32) as f64).sum::<f64>().sqrt();
                    let ny = b.iter().map(|q| (*q as f32 * *q as f32) as f64).sum::<f64>().sqrt();
                    if nx == 0.0 || ny == 0.0 {
                        0.0
                    } else {
                        (dot / (nx * ny)) as f32 as f64
                    }
                })
                .collect();
            GVal::F(data, None)
        }
        "haversine" => {
            let (la1, lo1, la2, lo2) = (arg(0)?.as_f(), arg(1)?.as_f(), arg(2)?.as_f(), arg(3)?.as_f());
            let data = (0..la1.len())
                .map(|i| {
                    ops::geo::haversine_km(
                        la1[i] as f32 as f64,
                        lo1[i] as f32 as f64,
                        la2[i] as f32 as f64,
                        lo2[i] as f32 as f64,
                    ) as f32 as f64
                })
                .collect();
            GVal::F(data, None)
        }
        other => return Err(KamaeError::Unsupported(format!("graph op: {other}"))),
    })
}

/// Evaluate a multi-output node: one shared pass over the input produces
/// every declared lane (`(bare_lane_name, value)` pairs).
///
/// Currently `multi_bucketize` is the only multi-output op (produced by
/// `optim::passes::MultiLaneBucketize`): the merged sorted-splits binary
/// search runs ONCE per value, and each lane replays its original
/// sibling node's exact arithmetic on top of it —
///
/// * `kind: "bucket"` — a merged-away `bucketize(x, splits_i)`. The
///   lane's `remap` table recovers the original bucket index from the
///   merged index (`remap[k]` = number of `splits_i` entries ≤ the k-th
///   merged prefix), exact on raw f64 because `splits_i` ⊆ merged splits
///   and both are sorted.
/// * `kind: "compare"` — a merged-away `compare_scalar(x, op, v)`,
///   replayed with its f32 operand rounding (shares the node's single
///   column walk, not the search — the rounding makes the search result
///   unusable for it).
/// * `kind: "bucket_compare"` — a merged-away single-output
///   `multi_bucketize` ladder (PR 2's bucketize→compare fusion):
///   remapped bucket index, then the f32-rounded threshold compare.
///
/// All three are bit-identical to the sibling nodes the optimizer merged.
fn eval_multi(node: &SpecNode, env: &HashMap<String, GVal>) -> Result<Vec<(String, GVal)>> {
    if node.op != "multi_bucketize" {
        return Err(KamaeError::Unsupported(format!(
            "multi-output graph op: {}",
            node.op
        )));
    }
    let input_name = node.inputs.first().ok_or_else(|| {
        KamaeError::InvalidConfig(format!("multi-output node {} has no input", node.id))
    })?;
    let x = env
        .get(input_name)
        .ok_or_else(|| KamaeError::ColumnNotFound(format!("{input_name} (graph value)")))?;
    let splits = attr_f64_array(&node.attrs, "splits")?;
    let xs = x.as_f();
    // the shared search: merged bucket index per value, raw f64 like
    // `bucketize`
    let merged: Vec<usize> = xs
        .iter()
        .map(|&v| splits.partition_point(|&s| s <= v))
        .collect();
    let mut out = Vec::with_capacity(node.lanes.len());
    for lane in &node.lanes {
        let a = &lane.attrs;
        let remap_for = |a: &Json| -> Result<Vec<i64>> {
            let remap = attr_i64_array(a, "remap")?;
            if remap.len() != splits.len() + 1 {
                return Err(KamaeError::Serde(format!(
                    "lane {}: remap table has {} entries for {} splits",
                    lane.name,
                    remap.len(),
                    splits.len()
                )));
            }
            Ok(remap)
        };
        let val = match a.req_str("kind")? {
            "bucket" => {
                let remap = remap_for(a)?;
                GVal::I(merged.iter().map(|&m| remap[m]).collect(), lane.width)
            }
            "compare" => {
                let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
                let value = a.req_f64("value")?;
                GVal::I(
                    xs.iter()
                        .map(|&v| op.apply_f64(v as f32 as f64, value as f32 as f64) as i64)
                        .collect(),
                    lane.width,
                )
            }
            "bucket_compare" => {
                let remap = remap_for(a)?;
                let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
                let value = a.req_f64("value")?;
                GVal::I(
                    merged
                        .iter()
                        .map(|&m| {
                            let bucket = remap[m];
                            op.apply_f64(bucket as f64 as f32 as f64, value as f32 as f64)
                                as i64
                        })
                        .collect(),
                    lane.width,
                )
            }
            other => {
                return Err(KamaeError::Unsupported(format!(
                    "multi_bucketize lane kind: {other}"
                )))
            }
        };
        out.push((lane.name.clone(), val));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;
    use crate::engine::Dataset;
    use crate::export::SpecInput;
    use crate::pipeline::{Pipeline, Stage};
    use crate::transformers::*;

    fn spec_roundtrip(spec: &GraphSpec) -> GraphSpec {
        GraphSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap()
    }

    #[test]
    fn end_to_end_interp_matches_engine() {
        // build a small mixed pipeline, fit, export, and check the
        // interpreter agrees with the engine's own transform
        let df = DataFrame::new(vec![
            ("price".into(), Column::from_f64(vec![10.0, 100.0, 1000.0])),
            ("city".into(), Column::from_str(vec!["NYC", "LON", "NYC"])),
            ("genres".into(), Column::from_str(vec!["a|b", "b", "c|a|b"])),
        ])
        .unwrap();
        let pipeline = Pipeline::new(vec![
            Stage::transformer(LogTransformer::new("price", "price_log")),
            Stage::transformer(HashIndexTransformer::new("city", "city_idx", 64)),
            Stage::transformer(StringToStringListTransformer::new("genres", "gl", "|", 3, "PAD")),
            Stage::estimator(crate::estimators::StringIndexEstimator::new("gl", "gl_idx").mask_token("PAD")),
            Stage::estimator(crate::estimators::StandardScaleEstimator::new("price_log", "price_z")),
        ]);
        let ds = Dataset::from_dataframe(df.clone(), 2);
        let model = pipeline.fit(&ds).unwrap();
        let engine_out = model.transform_df(df.clone()).unwrap();

        let spec = model
            .to_graph_spec(
                "t",
                vec![
                    SpecInput { name: "price".into(), dtype: DType::F64, width: None },
                    SpecInput { name: "city".into(), dtype: DType::Str, width: None },
                    SpecInput { name: "genres".into(), dtype: DType::Str, width: None },
                ],
                &["price_z", "city_idx", "gl_idx"],
            )
            .unwrap();
        let spec = spec_roundtrip(&spec);
        let interp = SpecInterpreter::new(spec);
        let out = interp.run(&df).unwrap();

        // price_z: f32 tolerance vs engine f64
        let pz_engine = engine_out.column("price_z").unwrap().as_f64().unwrap();
        let pz = out[0].as_f32().unwrap();
        for (a, b) in pz.iter().zip(pz_engine.iter()) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
        // city_idx: exact
        assert_eq!(
            out[1].as_i64().unwrap(),
            engine_out.column("city_idx").unwrap().as_i64().unwrap()
        );
        // gl_idx: exact, shape [3,3]
        assert_eq!(out[2].shape, vec![3, 3]);
        let l = engine_out.column("gl_idx").unwrap().as_list_i64().unwrap();
        assert_eq!(out[2].as_i64().unwrap(), &l.values[..]);
    }

    #[test]
    fn fused_ingress_matches_unfused_chain() {
        // fast path (trim->case->hash64 on Str) and replay path
        // (split_pad->hash64, not per-value) must both reproduce the
        // unfused chains exactly — including unicode, empties and nulls
        let df = DataFrame::new(vec![
            (
                "s".into(),
                Column::from_str(vec!["  Hello World ", "ACTION|comedy", "", " é|B "]),
            ),
        ])
        .unwrap();
        let node = |id: &str, op: &str, inputs: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let spec = |ingress: Vec<SpecNode>, tail: &str, width: Option<usize>| {
            let mut ingress = ingress;
            if let Some(last) = ingress.last_mut() {
                last.width = width;
            }
            GraphSpec {
                name: "t".into(),
                inputs: vec![SpecInput { name: "s".into(), dtype: DType::Str, width: None }],
                ingress,
                graph_inputs: vec![tail.to_string()],
                nodes: vec![SpecNode {
                    id: "out".into(),
                    op: "identity".into(),
                    inputs: vec![tail.to_string()],
                    attrs: Json::object(),
                    dtype: SpecDType::I64,
                    width,
                    lanes: vec![],
                }],
                outputs: vec!["out".into()],
            }
        };

        // --- fast path: trim -> case -> hash64 -------------------------
        let unfused = spec(
            vec![
                node("a", "trim", &["s"], "{}"),
                node("b", "case", &["a"], r#"{"mode": "lower"}"#),
                node("h", "hash64", &["b"], "{}"),
            ],
            "h",
            None,
        );
        let fused = spec(
            vec![node(
                "h",
                "fused_ingress",
                &["s"],
                r#"{"steps": [{"op": "trim"}, {"op": "case", "mode": "lower"}, {"op": "hash64"}]}"#,
            )],
            "h",
            None,
        );
        let a = SpecInterpreter::new(unfused).run(&df).unwrap();
        let b = SpecInterpreter::new(fused).run(&df).unwrap();
        assert_eq!(a, b);

        // --- replay path: split_pad -> hash64 (list output) ------------
        let unfused = spec(
            vec![
                node("sp", "split_pad", &["s"], r#"{"separator": "|", "list_length": 3, "default": "PAD"}"#),
                node("h", "hash64", &["sp"], "{}"),
            ],
            "h",
            Some(3),
        );
        let fused = spec(
            vec![node(
                "h",
                "fused_ingress",
                &["s"],
                r#"{"steps": [{"op": "split_pad", "separator": "|", "list_length": 3, "default": "PAD"}, {"op": "hash64"}]}"#,
            )],
            "h",
            Some(3),
        );
        let a = SpecInterpreter::new(unfused).run(&df).unwrap();
        let b = SpecInterpreter::new(fused).run(&df).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fused_graph_ops_match_unfused_pairs() {
        // multi_bucketize == compare_scalar(bucketize(x)) and
        // select_cmp == select(compare_scalar(x), a, b), bit-for-bit
        let df = DataFrame::new(vec![
            ("x".into(), Column::from_f64(vec![-2.5, -1.0, 0.0, 0.3, 1.0, 2.0, f64::NAN])),
            ("y".into(), Column::from_f64(vec![7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0])),
        ])
        .unwrap();
        let inputs = vec![
            SpecInput { name: "x".into(), dtype: DType::F64, width: None },
            SpecInput { name: "y".into(), dtype: DType::F64, width: None },
        ];
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str, dtype: SpecDType| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        };
        let run = |nodes: Vec<SpecNode>, outputs: &[&str]| {
            SpecInterpreter::new(GraphSpec {
                name: "t".into(),
                inputs: inputs.clone(),
                ingress: vec![],
                graph_inputs: vec!["x".into(), "y".into()],
                nodes,
                outputs: outputs.iter().map(|s| s.to_string()).collect(),
            })
            .run(&df)
            .unwrap()
        };

        let unfused = run(
            vec![
                node("b", "bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 1.0]}"#, SpecDType::I64),
                node("f", "compare_scalar", &["b"], r#"{"op": "ge", "value": 2.0}"#, SpecDType::I64),
                node("m", "compare_scalar", &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64),
                node("s", "select", &["m", "x", "y"], "{}", SpecDType::F32),
            ],
            &["f", "s"],
        );
        let fused = run(
            vec![
                node(
                    "f",
                    "multi_bucketize",
                    &["x"],
                    r#"{"splits": [-1.0, 0.0, 1.0], "op": "ge", "value": 2.0}"#,
                    SpecDType::I64,
                ),
                node("s", "select_cmp", &["x", "x", "y"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::F32),
            ],
            &["f", "s"],
        );
        assert_eq!(unfused[0], fused[0], "multi_bucketize diverged");
        // f32 NaN != NaN under PartialEq on the raw vecs — compare bits
        let (a, b) = (unfused[1].as_f32().unwrap(), fused[1].as_f32().unwrap());
        assert_eq!(a.len(), b.len());
        for (p, q) in a.iter().zip(b.iter()) {
            assert_eq!(p.to_bits(), q.to_bits(), "select_cmp diverged");
        }
    }

    #[test]
    fn multi_lane_bucketize_matches_sibling_nodes() {
        // one multi-output node with bucket / compare / bucket_compare
        // lanes must reproduce the separate sibling nodes bit-for-bit,
        // NaN and boundary values included
        use crate::export::SpecLane;

        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![-2.0, -1.0, -0.5, 0.0, 0.25, 0.5, 1.0, 7.0, f64::NAN]),
        )])
        .unwrap();
        let inputs = vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }];
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let run = |nodes: Vec<SpecNode>, outputs: &[&str]| {
            SpecInterpreter::new(GraphSpec {
                name: "t".into(),
                inputs: inputs.clone(),
                ingress: vec![],
                graph_inputs: vec!["x".into()],
                nodes,
                outputs: outputs.iter().map(|s| s.to_string()).collect(),
            })
            .run(&df)
            .unwrap()
        };

        let siblings = run(
            vec![
                node("b1", "bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 1.0]}"#),
                node("b2", "bucketize", &["x"], r#"{"splits": [0.5]}"#),
                node("c1", "compare_scalar", &["x"], r#"{"op": "gt", "value": 0.0}"#),
                node(
                    "f",
                    "multi_bucketize",
                    &["x"],
                    r#"{"splits": [-1.0, 0.0], "op": "ge", "value": 2.0}"#,
                ),
                node("n", "not", &["c1"], "{}"),
            ],
            &["b1", "b2", "c1", "f", "n"],
        );

        // merged splits: sorted union [-1, 0, 0.5, 1]
        let lane = |name: &str, attrs: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        let mut merged_node = node("x__lanes", "multi_bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 0.5, 1.0]}"#);
        merged_node.lanes = vec![
            lane("b1", r#"{"kind": "bucket", "remap": [0, 1, 2, 2, 3]}"#),
            lane("b2", r#"{"kind": "bucket", "remap": [0, 0, 0, 1, 1]}"#),
            lane("c1", r#"{"kind": "compare", "op": "gt", "value": 0.0}"#),
            lane(
                "f",
                r#"{"kind": "bucket_compare", "remap": [0, 1, 2, 2, 2], "op": "ge", "value": 2.0}"#,
            ),
        ];
        let merged = run(
            vec![
                merged_node,
                // a rewired consumer addressing a lane through the
                // qualified `id.lane` reference
                node("n", "not", &["x__lanes.c1"], "{}"),
            ],
            &["b1", "b2", "c1", "f", "n"],
        );
        assert_eq!(siblings, merged);
    }

    #[test]
    fn run_routed_matches_full_run_slices() {
        // a merged two-variant spec: routed evaluation over mixed row
        // groups must reproduce the matching row slices of the full run
        // bit-for-bit — shared nodes over the whole batch, exclusive
        // nodes over their group's rows only
        use crate::export::SpecInput;

        let node = |id: &str, op: &str, ins: &[&str], attrs: &str, dtype: SpecDType| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        };
        // variant a: log1p(x) and hashed city; variant b: the same
        // log1p (shared after merge keys match) plus an exclusive sqrt
        let mk = |name: &str, extra: bool| {
            let mut nodes = vec![node("xl", "log1p", &["x"], "{}", SpecDType::F32)];
            let mut outputs = vec!["xl".to_string(), "c_idx".to_string()];
            if extra {
                nodes.push(node("xs", "sqrt", &["x"], "{}", SpecDType::F32));
                outputs.push("xs".to_string());
            }
            nodes.push(node(
                "c_idx",
                "hash_bucket",
                &["c_h"],
                r#"{"num_bins": 32}"#,
                SpecDType::I64,
            ));
            GraphSpec {
                name: name.into(),
                inputs: vec![
                    SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                    SpecInput { name: "c".into(), dtype: DType::Str, width: None },
                ],
                ingress: vec![node("c_h", "hash64", &["c"], "{}", SpecDType::I64)],
                graph_inputs: vec!["x".into(), "c_h".into()],
                nodes,
                outputs,
            }
        };
        let a = mk("a", false);
        let b = mk("b", true);
        let merged = GraphSpec::merge_variants("a+b", &[&a, &b]).unwrap();
        let (merged, _) =
            crate::optim::optimize(merged, crate::optim::OptimizeLevel::Full).unwrap();

        let df = DataFrame::new(vec![
            (
                "x".into(),
                Column::from_f64(vec![0.5, 2.0, -1.0, 9.0, 4.0, 0.0, 16.0]),
            ),
            (
                "c".into(),
                Column::from_str(vec!["nyc", "lon", "par", "ber", "rio", "syd", "tok"]),
            ),
        ])
        .unwrap();
        let interp = SpecInterpreter::new(merged.clone());
        let full = interp.run(&df).unwrap();

        // rows 0..4 request variant a, rows 4..7 variant b
        let groups = vec![
            super::RouteGroup { outputs: merged.variant_outputs("a"), rows: 0..4 },
            super::RouteGroup { outputs: merged.variant_outputs("b"), rows: 4..7 },
        ];
        let routed = interp.run_routed(&df, &groups).unwrap();
        assert_eq!(routed.len(), 2);
        for (g, got) in groups.iter().zip(routed.iter()) {
            assert_eq!(got.len(), g.outputs.len());
            for (t, &oi) in got.iter().zip(g.outputs.iter()) {
                let expect = full[oi]
                    .split_batch(&[g.rows.start, g.rows.len(), df.num_rows() - g.rows.end])
                    .unwrap()
                    .swap_remove(1);
                assert_eq!(t, &expect, "output {} rows {:?}", merged.outputs[oi], g.rows);
            }
        }

        // same-variant-only batches route too (single group, full cover)
        let solo = vec![super::RouteGroup {
            outputs: merged.variant_outputs("a"),
            rows: 0..df.num_rows(),
        }];
        let routed = interp.run_routed(&df, &solo).unwrap();
        for (t, &oi) in routed[0].iter().zip(solo[0].outputs.iter()) {
            assert_eq!(t, &full[oi]);
        }

        // malformed group covers are rejected, not miscomputed
        let gap = vec![super::RouteGroup { outputs: vec![0], rows: 1..df.num_rows() }];
        assert!(interp.run_routed(&df, &gap).is_err());
        let short = vec![super::RouteGroup { outputs: vec![0], rows: 0..2 }];
        assert!(interp.run_routed(&df, &short).is_err());
    }

    #[test]
    fn regex_ingress_precompiles_and_stays_exact() {
        // the per-spec regex cache (standalone nodes AND fused-chain
        // steps) must reproduce the direct kernel output exactly
        let df = DataFrame::new(vec![(
            "s".into(),
            Column::from_str(vec!["item-12 x", "no digits", "éé-7", ""]),
        )])
        .unwrap();
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let spec = GraphSpec {
            name: "re".into(),
            inputs: vec![SpecInput { name: "s".into(), dtype: DType::Str, width: None }],
            ingress: vec![
                node("r1", "regex_replace", &["s"], r#"{"pattern": "[0-9]+", "rep": "#"}"#),
                node("h1", "hash64", &["r1"], "{}"),
                node(
                    "h2",
                    "fused_ingress",
                    &["s"],
                    r#"{"steps": [{"op": "regex_extract", "pattern": "([a-z]+)", "group": 1}, {"op": "hash64"}]}"#,
                ),
            ],
            graph_inputs: vec!["h1".into(), "h2".into()],
            nodes: vec![
                node("o1", "identity", &["h1"], "{}"),
                node("o2", "identity", &["h2"], "{}"),
            ],
            outputs: vec!["o1".into(), "o2".into()],
        };
        let interp = SpecInterpreter::new(spec);
        let out = interp.run(&df).unwrap();

        // oracle: the kernels applied directly, regexes compiled fresh
        let re1 = crate::ops::regex::Regex::new("[0-9]+").unwrap();
        let replaced =
            crate::ops::regex::regex_replace(df.column("s").unwrap(), &re1, "#").unwrap();
        let h1 = crate::ops::hash::hash64_column(&replaced).unwrap();
        assert_eq!(out[0].as_i64().unwrap(), h1.as_i64().unwrap());
        let re2 = crate::ops::regex::Regex::new("([a-z]+)").unwrap();
        let extracted =
            crate::ops::regex::regex_extract(df.column("s").unwrap(), &re2, 1).unwrap();
        let h2 = crate::ops::hash::hash64_column(&extracted).unwrap();
        assert_eq!(out[1].as_i64().unwrap(), h2.as_i64().unwrap());

        // an invalid pattern still fails at request time, not at load
        let bad = GraphSpec {
            name: "bad".into(),
            inputs: vec![SpecInput { name: "s".into(), dtype: DType::Str, width: None }],
            ingress: vec![node("r", "regex_replace", &["s"], r#"{"pattern": "[", "rep": ""}"#)],
            graph_inputs: vec![],
            nodes: vec![],
            outputs: vec![],
        };
        let interp = SpecInterpreter::new(bad);
        assert!(interp.run(&df).is_err());
    }

    #[test]
    fn ingress_only_produces_graph_inputs() {
        let df = DataFrame::new(vec![("city".into(), Column::from_str(vec!["NYC", "LON"]))]).unwrap();
        let t = HashIndexTransformer::new("city", "idx", 8);
        let model = crate::pipeline::PipelineModel { stages: vec![Box::new(t)] };
        let spec = model
            .to_graph_spec(
                "t",
                vec![SpecInput { name: "city".into(), dtype: DType::Str, width: None }],
                &["idx"],
            )
            .unwrap();
        let interp = SpecInterpreter::new(spec);
        let tensors = interp.run_ingress(&df).unwrap();
        assert_eq!(tensors.len(), 1);
        assert_eq!(tensors[0].shape, vec![2]);
        assert_eq!(
            tensors[0].as_i64().unwrap()[0],
            crate::ops::hash::fnv1a64("NYC")
        );
    }

    #[test]
    fn cone_cache_prewarms_variant_subsets() {
        // a two-variant spec shape: every output carries a "<variant>::"
        // prefix, so the cache must pre-warm the full set AND each
        // variant's subset — repeated lookups return the SAME Arc via
        // the lock-free warm path, and ad-hoc subsets memoise in the
        // cold half
        let node = |id: &str, input: &str| SpecNode {
            id: id.into(),
            op: "mul_scalar".into(),
            inputs: vec![input.into()],
            attrs: Json::parse(r#"{"c": 2.0}"#).unwrap(),
            dtype: SpecDType::F32,
            width: None,
            lanes: vec![],
        };
        let spec = GraphSpec {
            name: "t".into(),
            inputs: vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }],
            ingress: vec![],
            graph_inputs: vec!["x".into()],
            nodes: vec![node("a::o", "x"), node("b::p", "x")],
            outputs: vec!["a::o".into(), "b::p".into()],
        };
        assert_eq!(spec.variants(), vec!["a", "b"]);
        let interp = SpecInterpreter::new(spec);
        // warm keys: full set + one per variant
        assert_eq!(interp.cones.warm.len(), 3);
        for outputs in [vec![0usize, 1], vec![0], vec![1]] {
            let first = interp.cone_for(&outputs);
            let second = interp.cone_for(&outputs);
            assert!(
                std::sync::Arc::ptr_eq(&first, &second),
                "warm subset {outputs:?} was recomputed"
            );
        }
        // nothing above touched the cold memo
        assert!(interp.cones.cold.lock().unwrap().is_empty());
        // an ad-hoc subset (reversed order — no warm key matches) lands
        // in the cold memo and still memoises
        let adhoc = interp.cone_for(&[1, 0]);
        assert!(std::sync::Arc::ptr_eq(&adhoc, &interp.cone_for(&[1, 0])));
        assert_eq!(interp.cones.cold.lock().unwrap().len(), 1);
        // warm and cold agree on the cone itself
        assert_eq!(*interp.cone_for(&[0, 1]), interp.spec().ancestor_cone_of(&[0, 1]));
    }

    #[test]
    fn profile_times_every_node_and_stays_idempotent() {
        let df = DataFrame::new(vec![(
            "city".into(),
            Column::from_str(vec!["NYC", "LON", "SFO"]),
        )])
        .unwrap();
        let t = HashIndexTransformer::new("city", "idx", 8);
        let model = crate::pipeline::PipelineModel { stages: vec![Box::new(t)] };
        let spec = model
            .to_graph_spec(
                "t",
                vec![SpecInput { name: "city".into(), dtype: DType::Str, width: None }],
                &["idx"],
            )
            .unwrap();
        let interp = SpecInterpreter::new(spec.clone());
        let timings = interp.profile(&df, 3).unwrap();
        assert_eq!(timings.len(), spec.ingress.len() + spec.nodes.len());
        for t in &timings {
            assert!(t.mean_ns >= 0.0 && t.mean_ns.is_finite(), "{}: {}", t.op, t.mean_ns);
            assert_eq!(t.rows, 3);
        }
        // profiling must not perturb results: a fresh run still matches
        let a = interp.run(&df).unwrap();
        let b = SpecInterpreter::new(spec).run(&df).unwrap();
        assert_eq!(a, b);
    }
}
