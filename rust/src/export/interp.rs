//! GraphSpec interpreter.
//!
//! Executes an exported spec directly on DataFrames: the **ingress**
//! section (string ops) runs through the same `ops::` kernels the engine
//! uses, and the **graph** section is evaluated op-by-op over flat
//! buffers with the same semantics the JAX compiler emits.
//!
//! Three roles:
//! 1. the serving **ingress stage** (`run_ingress`) that feeds the
//!    compiled PJRT graph,
//! 2. the **interpreted serving baseline** (`run`) — columnar but
//!    uncompiled, the ablation point between the MLeap-like row
//!    interpreter and the compiled graph (experiment C3),
//! 3. the **parity oracle**: `run` output must match the compiled
//!    graph's output bit-for-bit on I64 and to f32 rounding on floats.

use std::collections::HashMap;

use crate::dataframe::{Column, DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::ops;
use crate::runtime::{Tensor, TensorData};
use crate::util::json::Json;

use super::spec::{GraphSpec, SpecDType, SpecNode};

/// Flat graph-side value: rows × width buffer of f64 or i64.
#[derive(Debug, Clone)]
enum GVal {
    F(Vec<f64>, Option<usize>),
    I(Vec<i64>, Option<usize>),
}

impl GVal {
    fn width(&self) -> Option<usize> {
        match self {
            GVal::F(_, w) | GVal::I(_, w) => *w,
        }
    }

    fn len(&self) -> usize {
        match self {
            GVal::F(v, w) => v.len() / w.unwrap_or(1),
            GVal::I(v, w) => v.len() / w.unwrap_or(1),
        }
    }

    fn as_f(&self) -> Vec<f64> {
        match self {
            GVal::F(v, _) => v.clone(),
            GVal::I(v, _) => v.iter().map(|&x| x as f64).collect(),
        }
    }

    fn as_i(&self) -> Result<Vec<i64>> {
        match self {
            GVal::I(v, _) => Ok(v.clone()),
            GVal::F(v, _) => Ok(v.iter().map(|&x| x as i64).collect()),
        }
    }

    fn to_tensor(&self, batch: usize) -> Tensor {
        let shape = match self.width() {
            Some(w) => vec![batch, w],
            None => vec![batch],
        };
        match self {
            // compiled graphs compute in f32 — match that dtype here
            GVal::F(v, _) => Tensor {
                data: TensorData::F32(v.iter().map(|&x| x as f32).collect()),
                shape,
            },
            GVal::I(v, _) => Tensor { data: TensorData::I64(v.clone()), shape },
        }
    }
}

/// Interpreter over one [`GraphSpec`].
pub struct SpecInterpreter {
    spec: GraphSpec,
    /// Every graph-section name the spec actually reads (node inputs +
    /// outputs), computed once so multi-output lane binding does not
    /// clone values for alias names nothing consumes (each lane may be
    /// addressed as `"id.lane"` AND by its bare name).
    referenced: std::collections::HashSet<String>,
}

impl SpecInterpreter {
    pub fn new(spec: GraphSpec) -> SpecInterpreter {
        let referenced = spec
            .nodes
            .iter()
            .flat_map(|n| n.inputs.iter())
            .chain(spec.outputs.iter())
            .cloned()
            .collect();
        SpecInterpreter { spec, referenced }
    }

    pub fn spec(&self) -> &GraphSpec {
        &self.spec
    }

    /// Run only the ingress section and marshal the graph inputs as
    /// tensors (the serving front-end for the compiled path).
    pub fn run_ingress(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let mut df = df.clone();
        for node in &self.spec.ingress {
            apply_ingress(node, &mut df)?;
        }
        let batch = df.num_rows();
        self.spec
            .graph_inputs
            .iter()
            .map(|name| {
                let gv = column_to_gval(df.column(name)?)?;
                // graph inputs declared F32 must arrive as f32 tensors,
                // I64 as i64 — resolve via spec meta
                let (dtype, _) = self.spec.graph_input_meta(name).ok_or_else(|| {
                    KamaeError::Serde(format!("graph input {name} missing meta"))
                })?;
                Ok(match (dtype, gv) {
                    (SpecDType::F32, gv) => gv_to_f32_tensor(gv, batch),
                    (SpecDType::I64, gv) => {
                        let w = gv.width();
                        let data = gv.as_i()?;
                        Tensor {
                            data: TensorData::I64(data),
                            shape: match w {
                                Some(w) => vec![batch, w],
                                None => vec![batch],
                            },
                        }
                    }
                })
            })
            .collect()
    }

    /// Full interpretation: ingress + graph sections. Output order and
    /// dtypes match the compiled artifact exactly.
    pub fn run(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let mut df = df.clone();
        for node in &self.spec.ingress {
            apply_ingress(node, &mut df)?;
        }
        let batch = df.num_rows();
        let mut env: HashMap<String, GVal> = HashMap::new();
        for name in &self.spec.graph_inputs {
            env.insert(name.clone(), column_to_gval(df.column(name)?)?);
        }
        for node in &self.spec.nodes {
            if node.lanes.is_empty() {
                let val = eval_node(node, &env)?;
                env.insert(node.id.clone(), val);
            } else {
                for (lane_name, val) in eval_multi(node, &env)? {
                    // lanes bind under the qualified `id.lane` reference
                    // AND the bare lane name (spec outputs resolve by
                    // bare name; rewired consumers use the qualified
                    // one) — but only actually-consumed names get a
                    // binding, so nothing is cloned for unused aliases
                    let qualified = node.lane_ref(&lane_name);
                    if self.referenced.contains(&qualified) {
                        if self.referenced.contains(&lane_name) {
                            env.insert(qualified, val.clone());
                            env.insert(lane_name, val);
                        } else {
                            env.insert(qualified, val);
                        }
                    } else {
                        env.insert(lane_name, val);
                    }
                }
            }
        }
        self.spec
            .outputs
            .iter()
            .map(|o| {
                env.get(o)
                    .map(|g| g.to_tensor(batch))
                    .ok_or_else(|| KamaeError::ColumnNotFound(format!("{o} (spec output)")))
            })
            .collect()
    }
}

fn gv_to_f32_tensor(gv: GVal, batch: usize) -> Tensor {
    let w = gv.width();
    let data: Vec<f32> = gv.as_f().iter().map(|&x| x as f32).collect();
    Tensor {
        data: TensorData::F32(data),
        shape: match w {
            Some(w) => vec![batch, w],
            None => vec![batch],
        },
    }
}

// ---------------------------------------------------------------------------
// ingress section — DataFrame column ops

fn apply_ingress(node: &SpecNode, df: &mut DataFrame) -> Result<()> {
    let cols: Vec<&Column> = node
        .inputs
        .iter()
        .map(|n| df.column(n))
        .collect::<Result<_>>()?;
    let out = ingress_op_column(&node.op, &node.attrs, &cols)?;
    df.set_column(node.id.clone(), out)
}

/// Evaluate one ingress op over already-resolved input columns. Shared
/// by [`apply_ingress`] (columns from the request DataFrame) and the
/// fused-chain replay (columns are in-flight intermediates that never
/// touch the DataFrame).
fn ingress_op_column(op: &str, a: &Json, cols: &[&Column]) -> Result<Column> {
    let input = |i: usize| -> Result<&Column> {
        cols.get(i).copied().ok_or_else(|| {
            KamaeError::InvalidConfig(format!("ingress op {op}: missing input {i}"))
        })
    };
    Ok(match op {
        "hash64" => ops::hash::hash64_column(input(0)?)?,
        "case" => {
            let mode = match a.req_str("mode")? {
                "upper" => ops::string_ops::CaseMode::Upper,
                "lower" => ops::string_ops::CaseMode::Lower,
                _ => ops::string_ops::CaseMode::Title,
            };
            ops::string_ops::change_case(input(0)?, mode)?
        }
        "trim" => ops::string_ops::trim(input(0)?)?,
        "substring" => ops::string_ops::substring(
            input(0)?,
            a.req_i64("start")? as usize,
            a.req_i64("len")? as usize,
        )?,
        "replace" => ops::string_ops::replace_literal(input(0)?, a.req_str("from")?, a.req_str("to")?)?,
        "regex_replace" => {
            let re = ops::regex::Regex::new(a.req_str("pattern")?)?;
            ops::regex::regex_replace(input(0)?, &re, a.req_str("rep")?)?
        }
        "regex_extract" => {
            let re = ops::regex::Regex::new(a.req_str("pattern")?)?;
            ops::regex::regex_extract(input(0)?, &re, a.req_i64("group")? as usize)?
        }
        "concat" => ops::string_ops::concat_cols(cols, a.req_str("separator")?)?,
        "split_pad" => {
            let split = ops::string_ops::split(input(0)?, a.req_str("separator")?)?;
            ops::string_ops::pad_list(&split, a.req_i64("list_length")? as usize, a.req_str("default")?)?
        }
        "join" => {
            let l = input(0)?.as_list_str()?;
            let sep = a.req_str("separator")?;
            Column::from_str(l.rows().map(|r| r.join(sep)).collect::<Vec<String>>())
        }
        "string_match" => {
            let mode = match a.req_str("mode")? {
                "starts_with" => ops::string_ops::MatchMode::StartsWith,
                "ends_with" => ops::string_ops::MatchMode::EndsWith,
                _ => ops::string_ops::MatchMode::Contains,
            };
            ops::string_ops::string_match(input(0)?, a.req_str("needle")?, mode)?
        }
        "str_len" => ops::string_ops::str_len(input(0)?)?,
        "date_to_days" => ops::date::date_to_days(input(0)?)?,
        "timestamp_to_seconds" => ops::date::timestamp_to_seconds(input(0)?)?,
        "element_at" => ops::array::element_at(input(0)?, a.req_i64("index")?)?,
        "slice_list" => ops::array::slice_list(
            input(0)?,
            a.req_i64("start")? as usize,
            a.req_i64("len")? as usize,
        )?,
        "pad_list" => ops::string_ops::pad_list(
            input(0)?,
            a.req_i64("len")? as usize,
            a.req_str("default")?,
        )?,
        "to_string" => ops::cast::cast(input(0)?, &DType::Str)?,
        "parse_number" => ops::cast::cast(input(0)?, &DType::F64)?,
        "fused_ingress" => run_fused_ingress(a, input(0)?)?,
        other => {
            return Err(KamaeError::Unsupported(format!("ingress op: {other}")))
        }
    })
}

// ---------------------------------------------------------------------------
// fused ingress chains (optim::passes::IngressFuse)

/// One per-value step of the fused string fast path.
enum StrStep {
    Trim,
    Case(ops::string_ops::CaseMode),
    Replace(String, String),
    Substring(usize, usize),
}

/// Execute a fused ingress chain. The common shape — per-value string
/// ops optionally terminated by `hash64` — runs as ONE walk over the
/// column (no intermediate column materialisation at all); anything
/// else replays the recorded steps with the exact column kernels the
/// separate nodes used. Both paths are bit-identical to the unfused
/// chain by construction.
fn run_fused_ingress(a: &Json, input: &Column) -> Result<Column> {
    let steps = a.req_array("steps")?;
    if let Some(out) = fused_string_walk(steps, input)? {
        return Ok(out);
    }
    let mut col = input.clone();
    for s in steps {
        col = ingress_op_column(s.req_str("op")?, s, &[&col])?;
    }
    Ok(col)
}

/// Single-walk fast path; `None` when the chain or input shape doesn't
/// qualify (the caller falls back to step replay).
fn fused_string_walk(steps: &[Json], input: &Column) -> Result<Option<Column>> {
    use crate::dataframe::ListColumn;
    use ops::string_ops as so;

    let mut chain: Vec<StrStep> = Vec::new();
    let mut hash_tail = false;
    for (i, s) in steps.iter().enumerate() {
        match s.req_str("op")? {
            "trim" => chain.push(StrStep::Trim),
            "case" => {
                let mode = match s.req_str("mode")? {
                    "upper" => so::CaseMode::Upper,
                    "lower" => so::CaseMode::Lower,
                    _ => so::CaseMode::Title,
                };
                chain.push(StrStep::Case(mode));
            }
            "replace" => chain.push(StrStep::Replace(
                s.req_str("from")?.to_string(),
                s.req_str("to")?.to_string(),
            )),
            "substring" => chain.push(StrStep::Substring(
                s.req_i64("start")? as usize,
                s.req_i64("len")? as usize,
            )),
            "hash64" if i == steps.len() - 1 => hash_tail = true,
            _ => return Ok(None),
        }
    }
    let apply = |s: &str| -> String {
        let mut cur = s.to_string();
        for step in &chain {
            cur = match step {
                StrStep::Trim => cur.trim().to_string(),
                StrStep::Case(mode) => so::case_value(&cur, *mode),
                StrStep::Replace(from, to) => cur.replace(from.as_str(), to.as_str()),
                StrStep::Substring(start, len) => so::substring_value(&cur, *start, *len),
            };
        }
        cur
    };
    Ok(match input {
        Column::Str(v, nulls) => Some(if hash_tail {
            Column::I64(
                v.iter().map(|s| ops::hash::fnv1a64(&apply(s))).collect(),
                nulls.clone(),
            )
        } else {
            Column::Str(v.iter().map(|s| apply(s.as_str())).collect(), nulls.clone())
        }),
        Column::ListStr(l) => Some(if hash_tail {
            Column::ListI64(ListColumn {
                values: l.values.iter().map(|s| ops::hash::fnv1a64(&apply(s))).collect(),
                offsets: l.offsets.clone(),
            })
        } else {
            Column::ListStr(ListColumn {
                values: l.values.iter().map(|s| apply(s.as_str())).collect(),
                offsets: l.offsets.clone(),
            })
        }),
        _ => None,
    })
}

// ---------------------------------------------------------------------------
// graph section — flat-buffer ops (the semantics model.py compiles)

fn column_to_gval(col: &Column) -> Result<GVal> {
    Ok(match col {
        Column::Bool(v, _) => GVal::I(v.iter().map(|&b| b as i64).collect(), None),
        Column::I32(v, _) => GVal::I(v.iter().map(|&x| x as i64).collect(), None),
        Column::I64(v, _) => GVal::I(v.clone(), None),
        Column::F32(v, _) => GVal::F(v.iter().map(|&x| x as f64).collect(), None),
        Column::F64(v, _) => GVal::F(v.clone(), None),
        Column::ListBool(l) => {
            let w = fixed_width(&l.offsets, "bool list")?;
            GVal::I(l.values.iter().map(|&b| b as i64).collect(), Some(w))
        }
        Column::ListI32(l) => {
            let w = fixed_width(&l.offsets, "int32 list")?;
            GVal::I(l.values.iter().map(|&x| x as i64).collect(), Some(w))
        }
        Column::ListI64(l) => {
            let w = fixed_width(&l.offsets, "int64 list")?;
            GVal::I(l.values.clone(), Some(w))
        }
        Column::ListF32(l) => {
            let w = fixed_width(&l.offsets, "float32 list")?;
            GVal::F(l.values.iter().map(|&x| x as f64).collect(), Some(w))
        }
        Column::ListF64(l) => {
            let w = fixed_width(&l.offsets, "float64 list")?;
            GVal::F(l.values.clone(), Some(w))
        }
        Column::Str(..) | Column::ListStr(_) => {
            return Err(KamaeError::Unsupported(
                "string column crossing into graph section (missing hash64?)".into(),
            ))
        }
    })
}

fn fixed_width(offsets: &[u32], what: &str) -> Result<usize> {
    if offsets.len() < 2 {
        return Ok(0);
    }
    let w = (offsets[1] - offsets[0]) as usize;
    for win in offsets.windows(2) {
        if (win[1] - win[0]) as usize != w {
            return Err(KamaeError::InvalidConfig(format!(
                "ragged {what} cannot enter the graph section"
            )));
        }
    }
    Ok(w)
}

fn attr_f64_array(a: &Json, key: &str) -> Result<Vec<f64>> {
    a.req_array(key)?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| KamaeError::Serde(format!("{key} entry"))))
        .collect()
}

fn attr_i64_array(a: &Json, key: &str) -> Result<Vec<i64>> {
    a.req_array(key)?
        .iter()
        .map(|v| v.as_i64().ok_or_else(|| KamaeError::Serde(format!("{key} entry"))))
        .collect()
}

fn eval_node(node: &SpecNode, env: &HashMap<String, GVal>) -> Result<GVal> {
    use ops::math::UnaryOp;
    let a = &node.attrs;
    let arg = |i: usize| -> Result<&GVal> {
        env.get(&node.inputs[i]).ok_or_else(|| {
            KamaeError::ColumnNotFound(format!("{} (graph value)", node.inputs[i]))
        })
    };

    // unary float ops share a table
    let unary_op: Option<UnaryOp> = match node.op.as_str() {
        "log" => Some(match a.opt_f64("base") {
            Some(b) => UnaryOp::Log { base: Some(b) },
            None => UnaryOp::Log { base: None },
        }),
        "log1p" => Some(UnaryOp::Log1p),
        "exp" => Some(UnaryOp::Exp),
        "sqrt" => Some(UnaryOp::Sqrt),
        "abs" => Some(UnaryOp::Abs),
        "neg" => Some(UnaryOp::Neg),
        "reciprocal" => Some(UnaryOp::Reciprocal),
        "round" => Some(UnaryOp::Round),
        "floor" => Some(UnaryOp::Floor),
        "ceil" => Some(UnaryOp::Ceil),
        "sin" => Some(UnaryOp::Sin),
        "cos" => Some(UnaryOp::Cos),
        "tanh" => Some(UnaryOp::Tanh),
        "sigmoid" => Some(UnaryOp::Sigmoid),
        "clip" => Some(UnaryOp::Clip { min: a.opt_f64("min"), max: a.opt_f64("max") }),
        "pow_scalar" => Some(UnaryOp::PowScalar { p: a.req_f64("p")? }),
        "add_scalar" => Some(UnaryOp::AddScalar { c: a.req_f64("c")? }),
        "sub_scalar" => Some(UnaryOp::SubScalar { c: a.req_f64("c")? }),
        "mul_scalar" => Some(UnaryOp::MulScalar { c: a.req_f64("c")? }),
        "div_scalar" => Some(UnaryOp::DivScalar { c: a.req_f64("c")? }),
        "scale_shift" => Some(UnaryOp::ScaleShift {
            scale: a.req_f64("scale")?,
            shift: a.req_f64("shift")?,
        }),
        _ => None,
    };
    if let Some(op) = unary_op {
        let x = arg(0)?;
        // match compiled-graph f32 intermediate rounding
        let data = x
            .as_f()
            .iter()
            .map(|&v| op.apply(v as f32 as f64) as f32 as f64)
            .collect();
        return Ok(GVal::F(data, x.width()));
    }

    // fused scalar-affine chain (produced by optim::passes::AffineFuse).
    // Replays the original per-node steps with the same f32 rounding, so
    // fused and unfused graphs agree bit-for-bit.
    if node.op == "affine" {
        let x = arg(0)?;
        let steps: Vec<UnaryOp> = a
            .req_array("steps")?
            .iter()
            .map(|s| {
                Ok(match s.req_str("op")? {
                    "add_scalar" => UnaryOp::AddScalar { c: s.req_f64("c")? },
                    "sub_scalar" => UnaryOp::SubScalar { c: s.req_f64("c")? },
                    "mul_scalar" => UnaryOp::MulScalar { c: s.req_f64("c")? },
                    "div_scalar" => UnaryOp::DivScalar { c: s.req_f64("c")? },
                    "scale_shift" => UnaryOp::ScaleShift {
                        scale: s.req_f64("scale")?,
                        shift: s.req_f64("shift")?,
                    },
                    other => {
                        return Err(KamaeError::Unsupported(format!("affine step: {other}")))
                    }
                })
            })
            .collect::<Result<_>>()?;
        let data = x
            .as_f()
            .iter()
            .map(|&v| {
                let mut y = v;
                for op in &steps {
                    y = op.apply(y as f32 as f64) as f32 as f64;
                }
                y
            })
            .collect();
        return Ok(GVal::F(data, x.width()));
    }

    // binary float ops
    if let Ok(op) = ops::math::BinOp::from_name(&node.op) {
        let (x, y) = (arg(0)?, arg(1)?);
        let (xv, yv) = (x.as_f(), y.as_f());
        let w = x.width().or(y.width());
        let data: Vec<f64> = match (x.width(), y.width()) {
            (Some(wx), None) => xv
                .iter()
                .enumerate()
                .map(|(i, &p)| op.apply(p as f32 as f64, yv[i / wx] as f32 as f64) as f32 as f64)
                .collect(),
            (None, Some(wy)) => yv
                .iter()
                .enumerate()
                .map(|(i, &q)| op.apply(xv[i / wy] as f32 as f64, q as f32 as f64) as f32 as f64)
                .collect(),
            _ => {
                if xv.len() != yv.len() {
                    return Err(KamaeError::LengthMismatch {
                        left: xv.len(),
                        right: yv.len(),
                        context: format!("graph op {}", node.op),
                    });
                }
                xv.iter()
                    .zip(yv.iter())
                    .map(|(&p, &q)| op.apply(p as f32 as f64, q as f32 as f64) as f32 as f64)
                    .collect()
            }
        };
        return Ok(GVal::F(data, w));
    }

    Ok(match node.op.as_str() {
        "identity" => arg(0)?.clone(),
        "to_f32" => GVal::F(arg(0)?.as_f(), arg(0)?.width()),
        "to_i64" => GVal::I(arg(0)?.as_i()?, arg(0)?.width()),
        "bucketize" => {
            let splits = attr_f64_array(a, "splits")?;
            let x = arg(0)?;
            GVal::I(
                x.as_f()
                    .iter()
                    .map(|&v| splits.partition_point(|&s| s <= v) as i64)
                    .collect(),
                x.width(),
            )
        }
        "columns_agg" => {
            let n = node.inputs.len() as f64;
            let agg = a.req_str("agg")?;
            let cols: Vec<Vec<f64>> = (0..node.inputs.len())
                .map(|i| Ok(arg(i)?.as_f()))
                .collect::<Result<_>>()?;
            let rows = cols[0].len();
            let data = (0..rows)
                .map(|r| {
                    let mut acc = cols[0][r];
                    for c in cols.iter().skip(1) {
                        acc = match agg {
                            "min" => acc.min(c[r]),
                            "max" => acc.max(c[r]),
                            _ => acc + c[r],
                        };
                    }
                    if agg == "mean" {
                        acc / n
                    } else {
                        acc
                    }
                })
                .collect();
            GVal::F(data, None)
        }
        "date_part" => {
            let part = ops::date::DatePart::from_name(a.req_str("part")?)?;
            let x = arg(0)?.as_i()?;
            GVal::I(x.iter().map(|&d| part.extract(d)).collect(), arg(0)?.width())
        }
        "sub_i64" => {
            let (x, y) = (arg(0)?.as_i()?, arg(1)?.as_i()?);
            GVal::I(x.iter().zip(y.iter()).map(|(&p, &q)| p - q).collect(), arg(0)?.width())
        }
        "add_scalar_i64" => {
            let c = a.req_i64("c")?;
            GVal::I(arg(0)?.as_i()?.iter().map(|&x| x + c).collect(), arg(0)?.width())
        }
        "floordiv_scalar_i64" => {
            let c = a.req_i64("c")?;
            GVal::I(
                arg(0)?.as_i()?.iter().map(|&x| x.div_euclid(c)).collect(),
                arg(0)?.width(),
            )
        }
        "compare" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let (x, y) = (arg(0)?.as_f(), arg(1)?.as_f());
            GVal::I(
                x.iter()
                    .zip(y.iter())
                    .map(|(&p, &q)| op.apply_f64(p as f32 as f64, q as f32 as f64) as i64)
                    .collect(),
                arg(0)?.width(),
            )
        }
        "compare_scalar" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let c = a.req_f64("value")?;
            GVal::I(
                arg(0)?
                    .as_f()
                    .iter()
                    .map(|&p| op.apply_f64(p as f32 as f64, c as f32 as f64) as i64)
                    .collect(),
                arg(0)?.width(),
            )
        }
        "eq_hash" => {
            let h = a.req_i64("value_hash")?;
            GVal::I(
                arg(0)?.as_i()?.iter().map(|&x| (x == h) as i64).collect(),
                arg(0)?.width(),
            )
        }
        "bool_op" => {
            let op = a.req_str("op")?;
            let (x, y) = (arg(0)?.as_i()?, arg(1)?.as_i()?);
            GVal::I(
                x.iter()
                    .zip(y.iter())
                    .map(|(&p, &q)| {
                        let (p, q) = (p != 0, q != 0);
                        (match op {
                            "and" => p && q,
                            "or" => p || q,
                            _ => p ^ q,
                        }) as i64
                    })
                    .collect(),
                arg(0)?.width(),
            )
        }
        "not" => GVal::I(
            arg(0)?.as_i()?.iter().map(|&x| (x == 0) as i64).collect(),
            arg(0)?.width(),
        ),
        "select" => {
            let c = arg(0)?.as_i()?;
            let (x, y) = (arg(1)?.as_f(), arg(2)?.as_f());
            GVal::F(
                c.iter()
                    .enumerate()
                    .map(|(i, &k)| if k != 0 { x[i] } else { y[i] })
                    .collect(),
                arg(1)?.width(),
            )
        }
        // fused select(compare_scalar(x), a, b) — optim::passes::SelectCmpFuse.
        // The predicate replays compare_scalar's exact arithmetic (f32-rounded
        // operands compared in f64), the branches copy raw values like select.
        "select_cmp" => {
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let value = a.req_f64("value")?;
            let c = arg(0)?.as_f();
            let (x, y) = (arg(1)?.as_f(), arg(2)?.as_f());
            GVal::F(
                c.iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        if op.apply_f64(v as f32 as f64, value as f32 as f64) {
                            x[i]
                        } else {
                            y[i]
                        }
                    })
                    .collect(),
                arg(1)?.width(),
            )
        }
        // fused compare_scalar(bucketize(x)) — optim::passes::BucketizeMerge.
        // One sorted-splits binary search per value (raw f64, exactly like
        // bucketize), then the threshold compare of the bucket index with
        // compare_scalar's f32 rounding discipline.
        "multi_bucketize" => {
            let splits = attr_f64_array(a, "splits")?;
            let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
            let value = a.req_f64("value")?;
            let x = arg(0)?;
            GVal::I(
                x.as_f()
                    .iter()
                    .map(|&v| {
                        let bucket = splits.partition_point(|&s| s <= v) as i64;
                        op.apply_f64(bucket as f64 as f32 as f64, value as f32 as f64) as i64
                    })
                    .collect(),
                x.width(),
            )
        }
        "is_nan" => GVal::I(
            arg(0)?.as_f().iter().map(|&x| x.is_nan() as i64).collect(),
            arg(0)?.width(),
        ),
        "assemble" => {
            let cols: Vec<Vec<f64>> = (0..node.inputs.len())
                .map(|i| Ok(arg(i)?.as_f()))
                .collect::<Result<_>>()?;
            let rows = cols[0].len();
            let w = cols.len();
            let mut data = Vec::with_capacity(rows * w);
            for r in 0..rows {
                for c in &cols {
                    data.push(c[r]);
                }
            }
            GVal::F(data, Some(w))
        }
        "vector_at" => {
            let idx = a.req_i64("index")? as usize;
            let x = arg(0)?;
            let w = x.width().ok_or_else(|| {
                KamaeError::InvalidConfig("vector_at on scalar".into())
            })?;
            GVal::F(x.as_f().chunks(w).map(|row| row[idx]).collect(), None)
        }
        "list_sum" | "list_mean" | "list_min" | "list_max" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("list agg on scalar".into()))?;
            let data = x
                .as_f()
                .chunks(w)
                .map(|row| match node.op.as_str() {
                    "list_sum" => row.iter().sum(),
                    "list_mean" => row.iter().sum::<f64>() / w as f64,
                    "list_min" => row.iter().copied().fold(f64::INFINITY, f64::min),
                    _ => row.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                })
                .collect();
            GVal::F(data, None)
        }
        "list_len" => {
            let x = arg(0)?;
            let w = x.width().unwrap_or(1) as i64;
            GVal::I(vec![w; x.len()], None)
        }
        "element_at" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("element_at on scalar".into()))?;
            let idx = a.req_i64("index")?;
            let j = if idx < 0 { w as i64 + idx } else { idx } as usize;
            match x {
                GVal::F(v, _) => GVal::F(v.chunks(w).map(|row| row[j]).collect(), None),
                GVal::I(v, _) => GVal::I(v.chunks(w).map(|row| row[j]).collect(), None),
            }
        }
        "slice_list" => {
            let x = arg(0)?;
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("slice_list on scalar".into()))?;
            let start = a.req_i64("start")? as usize;
            let len = a.req_i64("len")? as usize;
            let s = start.min(w);
            let e = (start + len).min(w);
            match x {
                GVal::F(v, _) => GVal::F(
                    v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                    Some(e - s),
                ),
                GVal::I(v, _) => GVal::I(
                    v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                    Some(e - s),
                ),
            }
        }
        "hash_bucket" => {
            let bins = a.req_i64("num_bins")?;
            let x = arg(0)?;
            GVal::I(
                x.as_i()?.iter().map(|&h| ops::hash::bucket(h, 0, bins)).collect(),
                x.width(),
            )
        }
        "bloom_encode" => {
            let k = a.req_i64("num_hashes")? as usize;
            let bins = a.req_i64("num_bins")?;
            let x = arg(0)?.as_i()?;
            let mut data = Vec::with_capacity(x.len() * k);
            for &h in &x {
                for j in 0..k {
                    data.push(j as i64 * bins + ops::hash::bucket(h, j, bins));
                }
            }
            GVal::I(data, Some(k))
        }
        "vocab_lookup" => {
            let hashes = attr_i64_array(a, "vocab_hashes")?;
            let ranks = attr_i64_array(a, "vocab_ranks")?;
            let num_oov = a.req_i64("num_oov")?;
            let base = a.req_i64("base")?;
            let mask_hash = a.opt_i64("mask_hash");
            let x = arg(0)?;
            let data = x
                .as_i()?
                .iter()
                .map(|&h| {
                    if Some(h) == mask_hash {
                        return 0;
                    }
                    match hashes.binary_search(&h) {
                        Ok(i) => base + num_oov + ranks[i],
                        Err(_) => base + ops::hash::bucket(h, 0, num_oov),
                    }
                })
                .collect();
            GVal::I(data, x.width())
        }
        "one_hot" => {
            let hashes = attr_i64_array(a, "vocab_hashes")?;
            let ranks = attr_i64_array(a, "vocab_ranks")?;
            let num_oov = a.req_i64("num_oov")? as usize;
            let drop_unseen = a.opt_bool("drop_unseen").unwrap_or(false);
            let depth = if drop_unseen {
                hashes.len()
            } else {
                num_oov + hashes.len()
            };
            let x = arg(0)?.as_i()?;
            let mut data = vec![0.0f64; x.len() * depth];
            for (i, &h) in x.iter().enumerate() {
                let hot = match hashes.binary_search(&h) {
                    Ok(j) => Some(if drop_unseen {
                        ranks[j] as usize
                    } else {
                        num_oov + ranks[j] as usize
                    }),
                    Err(_) => {
                        if drop_unseen {
                            None
                        } else {
                            Some(ops::hash::bucket(h, 0, num_oov as i64) as usize)
                        }
                    }
                };
                if let Some(hpos) = hot {
                    data[i * depth + hpos] = 1.0;
                }
            }
            GVal::F(data, Some(depth))
        }
        "scale_vec" => {
            let scale = attr_f64_array(a, "scale")?;
            let shift = attr_f64_array(a, "shift")?;
            let x = arg(0)?;
            let w = x.width().unwrap_or(1);
            if scale.len() != w {
                return Err(KamaeError::LengthMismatch {
                    left: scale.len(),
                    right: w,
                    context: "scale_vec width".into(),
                });
            }
            let data = x
                .as_f()
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    ((v as f32) * (scale[i % w] as f32) + (shift[i % w] as f32)) as f64
                })
                .collect();
            GVal::F(data, x.width())
        }
        "impute" => {
            let fill = a.req_f64("fill")?;
            let mask = a.opt_f64("mask_value");
            let x = arg(0)?;
            let data = x
                .as_f()
                .iter()
                .map(|&v| {
                    if v.is_nan() || Some(v) == mask {
                        fill as f32 as f64
                    } else {
                        v as f32 as f64
                    }
                })
                .collect();
            GVal::F(data, x.width())
        }
        "cosine_similarity" => {
            let (x, y) = (arg(0)?, arg(1)?);
            let w = x
                .width()
                .ok_or_else(|| KamaeError::InvalidConfig("cosine on scalar".into()))?;
            let (xv, yv) = (x.as_f(), y.as_f());
            let data = xv
                .chunks(w)
                .zip(yv.chunks(w))
                .map(|(a, b)| {
                    let dot: f64 = a.iter().zip(b.iter()).map(|(p, q)| (*p as f32 * *q as f32) as f64).sum();
                    let nx = a.iter().map(|p| (*p as f32 * *p as f32) as f64).sum::<f64>().sqrt();
                    let ny = b.iter().map(|q| (*q as f32 * *q as f32) as f64).sum::<f64>().sqrt();
                    if nx == 0.0 || ny == 0.0 {
                        0.0
                    } else {
                        (dot / (nx * ny)) as f32 as f64
                    }
                })
                .collect();
            GVal::F(data, None)
        }
        "haversine" => {
            let (la1, lo1, la2, lo2) = (arg(0)?.as_f(), arg(1)?.as_f(), arg(2)?.as_f(), arg(3)?.as_f());
            let data = (0..la1.len())
                .map(|i| {
                    ops::geo::haversine_km(
                        la1[i] as f32 as f64,
                        lo1[i] as f32 as f64,
                        la2[i] as f32 as f64,
                        lo2[i] as f32 as f64,
                    ) as f32 as f64
                })
                .collect();
            GVal::F(data, None)
        }
        other => return Err(KamaeError::Unsupported(format!("graph op: {other}"))),
    })
}

/// Evaluate a multi-output node: one shared pass over the input produces
/// every declared lane (`(bare_lane_name, value)` pairs).
///
/// Currently `multi_bucketize` is the only multi-output op (produced by
/// `optim::passes::MultiLaneBucketize`): the merged sorted-splits binary
/// search runs ONCE per value, and each lane replays its original
/// sibling node's exact arithmetic on top of it —
///
/// * `kind: "bucket"` — a merged-away `bucketize(x, splits_i)`. The
///   lane's `remap` table recovers the original bucket index from the
///   merged index (`remap[k]` = number of `splits_i` entries ≤ the k-th
///   merged prefix), exact on raw f64 because `splits_i` ⊆ merged splits
///   and both are sorted.
/// * `kind: "compare"` — a merged-away `compare_scalar(x, op, v)`,
///   replayed with its f32 operand rounding (shares the node's single
///   column walk, not the search — the rounding makes the search result
///   unusable for it).
/// * `kind: "bucket_compare"` — a merged-away single-output
///   `multi_bucketize` ladder (PR 2's bucketize→compare fusion):
///   remapped bucket index, then the f32-rounded threshold compare.
///
/// All three are bit-identical to the sibling nodes the optimizer merged.
fn eval_multi(node: &SpecNode, env: &HashMap<String, GVal>) -> Result<Vec<(String, GVal)>> {
    if node.op != "multi_bucketize" {
        return Err(KamaeError::Unsupported(format!(
            "multi-output graph op: {}",
            node.op
        )));
    }
    let input_name = node.inputs.first().ok_or_else(|| {
        KamaeError::InvalidConfig(format!("multi-output node {} has no input", node.id))
    })?;
    let x = env
        .get(input_name)
        .ok_or_else(|| KamaeError::ColumnNotFound(format!("{input_name} (graph value)")))?;
    let splits = attr_f64_array(&node.attrs, "splits")?;
    let xs = x.as_f();
    // the shared search: merged bucket index per value, raw f64 like
    // `bucketize`
    let merged: Vec<usize> = xs
        .iter()
        .map(|&v| splits.partition_point(|&s| s <= v))
        .collect();
    let mut out = Vec::with_capacity(node.lanes.len());
    for lane in &node.lanes {
        let a = &lane.attrs;
        let remap_for = |a: &Json| -> Result<Vec<i64>> {
            let remap = attr_i64_array(a, "remap")?;
            if remap.len() != splits.len() + 1 {
                return Err(KamaeError::Serde(format!(
                    "lane {}: remap table has {} entries for {} splits",
                    lane.name,
                    remap.len(),
                    splits.len()
                )));
            }
            Ok(remap)
        };
        let val = match a.req_str("kind")? {
            "bucket" => {
                let remap = remap_for(a)?;
                GVal::I(merged.iter().map(|&m| remap[m]).collect(), lane.width)
            }
            "compare" => {
                let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
                let value = a.req_f64("value")?;
                GVal::I(
                    xs.iter()
                        .map(|&v| op.apply_f64(v as f32 as f64, value as f32 as f64) as i64)
                        .collect(),
                    lane.width,
                )
            }
            "bucket_compare" => {
                let remap = remap_for(a)?;
                let op = ops::logical::CmpOp::from_name(a.req_str("op")?)?;
                let value = a.req_f64("value")?;
                GVal::I(
                    merged
                        .iter()
                        .map(|&m| {
                            let bucket = remap[m];
                            op.apply_f64(bucket as f64 as f32 as f64, value as f32 as f64)
                                as i64
                        })
                        .collect(),
                    lane.width,
                )
            }
            other => {
                return Err(KamaeError::Unsupported(format!(
                    "multi_bucketize lane kind: {other}"
                )))
            }
        };
        out.push((lane.name.clone(), val));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;
    use crate::engine::Dataset;
    use crate::export::SpecInput;
    use crate::pipeline::{Pipeline, Stage};
    use crate::transformers::*;

    fn spec_roundtrip(spec: &GraphSpec) -> GraphSpec {
        GraphSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap()).unwrap()
    }

    #[test]
    fn end_to_end_interp_matches_engine() {
        // build a small mixed pipeline, fit, export, and check the
        // interpreter agrees with the engine's own transform
        let df = DataFrame::new(vec![
            ("price".into(), Column::from_f64(vec![10.0, 100.0, 1000.0])),
            ("city".into(), Column::from_str(vec!["NYC", "LON", "NYC"])),
            ("genres".into(), Column::from_str(vec!["a|b", "b", "c|a|b"])),
        ])
        .unwrap();
        let pipeline = Pipeline::new(vec![
            Stage::transformer(LogTransformer::new("price", "price_log")),
            Stage::transformer(HashIndexTransformer::new("city", "city_idx", 64)),
            Stage::transformer(StringToStringListTransformer::new("genres", "gl", "|", 3, "PAD")),
            Stage::estimator(crate::estimators::StringIndexEstimator::new("gl", "gl_idx").mask_token("PAD")),
            Stage::estimator(crate::estimators::StandardScaleEstimator::new("price_log", "price_z")),
        ]);
        let ds = Dataset::from_dataframe(df.clone(), 2);
        let model = pipeline.fit(&ds).unwrap();
        let engine_out = model.transform_df(df.clone()).unwrap();

        let spec = model
            .to_graph_spec(
                "t",
                vec![
                    SpecInput { name: "price".into(), dtype: DType::F64, width: None },
                    SpecInput { name: "city".into(), dtype: DType::Str, width: None },
                    SpecInput { name: "genres".into(), dtype: DType::Str, width: None },
                ],
                &["price_z", "city_idx", "gl_idx"],
            )
            .unwrap();
        let spec = spec_roundtrip(&spec);
        let interp = SpecInterpreter::new(spec);
        let out = interp.run(&df).unwrap();

        // price_z: f32 tolerance vs engine f64
        let pz_engine = engine_out.column("price_z").unwrap().as_f64().unwrap();
        let pz = out[0].as_f32().unwrap();
        for (a, b) in pz.iter().zip(pz_engine.iter()) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
        // city_idx: exact
        assert_eq!(
            out[1].as_i64().unwrap(),
            engine_out.column("city_idx").unwrap().as_i64().unwrap()
        );
        // gl_idx: exact, shape [3,3]
        assert_eq!(out[2].shape, vec![3, 3]);
        let l = engine_out.column("gl_idx").unwrap().as_list_i64().unwrap();
        assert_eq!(out[2].as_i64().unwrap(), &l.values[..]);
    }

    #[test]
    fn fused_ingress_matches_unfused_chain() {
        // fast path (trim->case->hash64 on Str) and replay path
        // (split_pad->hash64, not per-value) must both reproduce the
        // unfused chains exactly — including unicode, empties and nulls
        let df = DataFrame::new(vec![
            (
                "s".into(),
                Column::from_str(vec!["  Hello World ", "ACTION|comedy", "", " é|B "]),
            ),
        ])
        .unwrap();
        let node = |id: &str, op: &str, inputs: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let spec = |ingress: Vec<SpecNode>, tail: &str, width: Option<usize>| {
            let mut ingress = ingress;
            if let Some(last) = ingress.last_mut() {
                last.width = width;
            }
            GraphSpec {
                name: "t".into(),
                inputs: vec![SpecInput { name: "s".into(), dtype: DType::Str, width: None }],
                ingress,
                graph_inputs: vec![tail.to_string()],
                nodes: vec![SpecNode {
                    id: "out".into(),
                    op: "identity".into(),
                    inputs: vec![tail.to_string()],
                    attrs: Json::object(),
                    dtype: SpecDType::I64,
                    width,
                    lanes: vec![],
                }],
                outputs: vec!["out".into()],
            }
        };

        // --- fast path: trim -> case -> hash64 -------------------------
        let unfused = spec(
            vec![
                node("a", "trim", &["s"], "{}"),
                node("b", "case", &["a"], r#"{"mode": "lower"}"#),
                node("h", "hash64", &["b"], "{}"),
            ],
            "h",
            None,
        );
        let fused = spec(
            vec![node(
                "h",
                "fused_ingress",
                &["s"],
                r#"{"steps": [{"op": "trim"}, {"op": "case", "mode": "lower"}, {"op": "hash64"}]}"#,
            )],
            "h",
            None,
        );
        let a = SpecInterpreter::new(unfused).run(&df).unwrap();
        let b = SpecInterpreter::new(fused).run(&df).unwrap();
        assert_eq!(a, b);

        // --- replay path: split_pad -> hash64 (list output) ------------
        let unfused = spec(
            vec![
                node("sp", "split_pad", &["s"], r#"{"separator": "|", "list_length": 3, "default": "PAD"}"#),
                node("h", "hash64", &["sp"], "{}"),
            ],
            "h",
            Some(3),
        );
        let fused = spec(
            vec![node(
                "h",
                "fused_ingress",
                &["s"],
                r#"{"steps": [{"op": "split_pad", "separator": "|", "list_length": 3, "default": "PAD"}, {"op": "hash64"}]}"#,
            )],
            "h",
            Some(3),
        );
        let a = SpecInterpreter::new(unfused).run(&df).unwrap();
        let b = SpecInterpreter::new(fused).run(&df).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fused_graph_ops_match_unfused_pairs() {
        // multi_bucketize == compare_scalar(bucketize(x)) and
        // select_cmp == select(compare_scalar(x), a, b), bit-for-bit
        let df = DataFrame::new(vec![
            ("x".into(), Column::from_f64(vec![-2.5, -1.0, 0.0, 0.3, 1.0, 2.0, f64::NAN])),
            ("y".into(), Column::from_f64(vec![7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0])),
        ])
        .unwrap();
        let inputs = vec![
            SpecInput { name: "x".into(), dtype: DType::F64, width: None },
            SpecInput { name: "y".into(), dtype: DType::F64, width: None },
        ];
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str, dtype: SpecDType| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        };
        let run = |nodes: Vec<SpecNode>, outputs: &[&str]| {
            SpecInterpreter::new(GraphSpec {
                name: "t".into(),
                inputs: inputs.clone(),
                ingress: vec![],
                graph_inputs: vec!["x".into(), "y".into()],
                nodes,
                outputs: outputs.iter().map(|s| s.to_string()).collect(),
            })
            .run(&df)
            .unwrap()
        };

        let unfused = run(
            vec![
                node("b", "bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 1.0]}"#, SpecDType::I64),
                node("f", "compare_scalar", &["b"], r#"{"op": "ge", "value": 2.0}"#, SpecDType::I64),
                node("m", "compare_scalar", &["x"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::I64),
                node("s", "select", &["m", "x", "y"], "{}", SpecDType::F32),
            ],
            &["f", "s"],
        );
        let fused = run(
            vec![
                node(
                    "f",
                    "multi_bucketize",
                    &["x"],
                    r#"{"splits": [-1.0, 0.0, 1.0], "op": "ge", "value": 2.0}"#,
                    SpecDType::I64,
                ),
                node("s", "select_cmp", &["x", "x", "y"], r#"{"op": "gt", "value": 0.0}"#, SpecDType::F32),
            ],
            &["f", "s"],
        );
        assert_eq!(unfused[0], fused[0], "multi_bucketize diverged");
        // f32 NaN != NaN under PartialEq on the raw vecs — compare bits
        let (a, b) = (unfused[1].as_f32().unwrap(), fused[1].as_f32().unwrap());
        assert_eq!(a.len(), b.len());
        for (p, q) in a.iter().zip(b.iter()) {
            assert_eq!(p.to_bits(), q.to_bits(), "select_cmp diverged");
        }
    }

    #[test]
    fn multi_lane_bucketize_matches_sibling_nodes() {
        // one multi-output node with bucket / compare / bucket_compare
        // lanes must reproduce the separate sibling nodes bit-for-bit,
        // NaN and boundary values included
        use crate::export::SpecLane;

        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![-2.0, -1.0, -0.5, 0.0, 0.25, 0.5, 1.0, 7.0, f64::NAN]),
        )])
        .unwrap();
        let inputs = vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }];
        let node = |id: &str, op: &str, ins: &[&str], attrs: &str| SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
            lanes: vec![],
        };
        let run = |nodes: Vec<SpecNode>, outputs: &[&str]| {
            SpecInterpreter::new(GraphSpec {
                name: "t".into(),
                inputs: inputs.clone(),
                ingress: vec![],
                graph_inputs: vec!["x".into()],
                nodes,
                outputs: outputs.iter().map(|s| s.to_string()).collect(),
            })
            .run(&df)
            .unwrap()
        };

        let siblings = run(
            vec![
                node("b1", "bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 1.0]}"#),
                node("b2", "bucketize", &["x"], r#"{"splits": [0.5]}"#),
                node("c1", "compare_scalar", &["x"], r#"{"op": "gt", "value": 0.0}"#),
                node(
                    "f",
                    "multi_bucketize",
                    &["x"],
                    r#"{"splits": [-1.0, 0.0], "op": "ge", "value": 2.0}"#,
                ),
                node("n", "not", &["c1"], "{}"),
            ],
            &["b1", "b2", "c1", "f", "n"],
        );

        // merged splits: sorted union [-1, 0, 0.5, 1]
        let lane = |name: &str, attrs: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        let mut merged_node = node("x__lanes", "multi_bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 0.5, 1.0]}"#);
        merged_node.lanes = vec![
            lane("b1", r#"{"kind": "bucket", "remap": [0, 1, 2, 2, 3]}"#),
            lane("b2", r#"{"kind": "bucket", "remap": [0, 0, 0, 1, 1]}"#),
            lane("c1", r#"{"kind": "compare", "op": "gt", "value": 0.0}"#),
            lane(
                "f",
                r#"{"kind": "bucket_compare", "remap": [0, 1, 2, 2, 2], "op": "ge", "value": 2.0}"#,
            ),
        ];
        let merged = run(
            vec![
                merged_node,
                // a rewired consumer addressing a lane through the
                // qualified `id.lane` reference
                node("n", "not", &["x__lanes.c1"], "{}"),
            ],
            &["b1", "b2", "c1", "f", "n"],
        );
        assert_eq!(siblings, merged);
    }

    #[test]
    fn ingress_only_produces_graph_inputs() {
        let df = DataFrame::new(vec![("city".into(), Column::from_str(vec!["NYC", "LON"]))]).unwrap();
        let t = HashIndexTransformer::new("city", "idx", 8);
        let model = crate::pipeline::PipelineModel { stages: vec![Box::new(t)] };
        let spec = model
            .to_graph_spec(
                "t",
                vec![SpecInput { name: "city".into(), dtype: DType::Str, width: None }],
                &["idx"],
            )
            .unwrap();
        let interp = SpecInterpreter::new(spec);
        let tensors = interp.run_ingress(&df).unwrap();
        assert_eq!(tensors.len(), 1);
        assert_eq!(tensors[0].shape, vec![2]);
        assert_eq!(
            tensors[0].as_i64().unwrap()[0],
            crate::ops::hash::fnv1a64("NYC")
        );
    }
}
