//! GraphSpec — the export IR between the fitted Rust pipeline and the
//! compiled inference graph.
//!
//! This is the reproduction's analogue of Kamae's `build_keras_model()`:
//! a fitted [`crate::pipeline::PipelineModel`] exports a **GraphSpec**
//! (JSON), which `python/compile/model.py` compiles to a JAX function
//! (calling the Pallas kernels) and `python/compile/aot.py` lowers to HLO
//! text for the PJRT runtime.
//!
//! The full lifecycle is **export → optimize → compile/interpret**: the
//! builder emits the fitted pipeline verbatim, then the
//! [`crate::optim`] pass manager rewrites the spec (dead-node
//! elimination, identity/no-op-cast removal, constant folding, CSE,
//! scalar-affine fusion) before it reaches the compiler or the
//! interpreter. `PipelineModel::to_graph_spec` optimizes by default;
//! the op vocabulary shared by the builder, the interpreter and
//! `model.py` is declared once in [`crate::optim::registry`].
//!
//! A spec has two sections, split automatically by the builder:
//!
//! * **ingress** — string-typed ops (split, regex, case, concat, date
//!   parsing, string→hash64). HLO has no string dtype, so these execute in
//!   Rust at serving time, *reusing the exact engine kernels* — one
//!   implementation on both sides of the train/serve boundary (the
//!   paper's parity argument, DESIGN.md §Substitutions).
//! * **nodes** — numeric ops compiled into the graph. All tensors are
//!   `float32` or `int64`; scalar features have shape `[B]`, fixed-width
//!   sequence features `[B, W]`.
//!
//! A graph node may be **multi-output**: it declares named
//! [`SpecLane`]s and consumers reference `"<node_id>.<lane_name>"` (or
//! the lane's bare name — lanes share the column namespace). The
//! builder never emits these; the optimizer's multi-lane passes do.
//!
//! On the serving side the full pipeline is **spec → optimized IR →
//! kernel program → pooled server**: at backend load the
//! [`SpecInterpreter`] compiles the (already optimizer-rewritten) spec
//! once into a [`kernel`] program — a topologically ordered list of
//! typed kernels with pre-parsed attributes and slot-indexed buffers —
//! and every request (`run`, and `run_routed`'s per-cone sub-programs)
//! executes through it. The original `eval_node` interpreter is retained
//! verbatim as the differential oracle the kernels are pinned against;
//! specs the kernel compiler cannot handle fall back to it silently.

mod builder;
mod interp;
mod kernel;
mod spec;

pub use builder::SpecBuilder;
pub use interp::{NodeTiming, RouteGroup, SpecInterpreter};
pub use spec::{Cone, GraphSpec, SpecDType, SpecInput, SpecLane, SpecNode};
