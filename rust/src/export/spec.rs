//! GraphSpec data model and JSON (de)serialisation.

use crate::dataframe::DType;
use crate::error::{KamaeError, Result};
use crate::util::json::Json;

/// Tensor dtype inside the compiled graph. The whole graph runs on two
/// dtypes: `F32` for continuous features, `I64` for indices/hashes/dates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecDType {
    F32,
    I64,
}

impl SpecDType {
    pub fn name(&self) -> &'static str {
        match self {
            SpecDType::F32 => "float32",
            SpecDType::I64 => "int64",
        }
    }

    pub fn parse(s: &str) -> Result<SpecDType> {
        match s {
            "float32" => Ok(SpecDType::F32),
            "int64" => Ok(SpecDType::I64),
            other => Err(KamaeError::Serde(format!("bad spec dtype: {other}"))),
        }
    }

    /// Graph dtype for an engine column dtype (strings hash to I64).
    pub fn for_engine(dt: &DType) -> SpecDType {
        match dt {
            DType::I32 | DType::I64 | DType::Bool | DType::Str => SpecDType::I64,
            DType::F32 | DType::F64 => SpecDType::F32,
            DType::List(inner) => SpecDType::for_engine(inner),
        }
    }
}

/// A raw feature the serving request supplies.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecInput {
    pub name: String,
    /// Engine dtype of the raw feature (may be `string`, `array<string>`…).
    pub dtype: DType,
    /// Fixed sequence width, `None` for scalars. List-typed inputs MUST
    /// declare a width — ragged data cannot cross into the compiled graph.
    pub width: Option<usize>,
}

/// One named output lane of a multi-output node.
///
/// A node may declare N lanes instead of a single output value; each
/// lane is addressable by consumers as `"<node_id>.<lane_name>"` AND by
/// its bare `name` (lane names live in the node/column namespace, which
/// is what lets a lane keep serving a spec output whose producing node
/// the optimizer merged away — spec outputs are never renamed).
#[derive(Debug, Clone, PartialEq)]
pub struct SpecLane {
    /// Lane name. Must be unique across the whole graph section (it is
    /// a column name); the optimizer uses the merged-away node's id.
    pub name: String,
    /// Per-lane op parameters (e.g. a bucket remap table or a compare
    /// op/threshold) — the node-level `attrs` carry the shared work.
    pub attrs: Json,
    pub dtype: SpecDType,
    pub width: Option<usize>,
}

/// One operation in the spec (ingress or graph section).
#[derive(Debug, Clone, PartialEq)]
pub struct SpecNode {
    /// Output column name (ids and column names share one namespace).
    /// For a multi-output node the id only namespaces its lanes — the
    /// bare id is not itself a value.
    pub id: String,
    /// Op name — the contract with `python/compile/model.py::OPS` and
    /// [`super::interp`].
    pub op: String,
    /// Input column names. An entry may be a lane reference
    /// `"<node_id>.<lane_name>"` into a multi-output node.
    pub inputs: Vec<String>,
    /// Scalar attributes (and constants such as vocab hashes — kept in
    /// `attrs` as JSON arrays; i64 precision is preserved by our JSON).
    pub attrs: Json,
    /// Output dtype in the graph (`F32`/`I64`); for ingress nodes this is
    /// the *engine* view's graph projection once hashed. Ignored by
    /// consumers when `lanes` is non-empty (each lane carries its own).
    pub dtype: SpecDType,
    /// Output sequence width (`None` = scalar).
    pub width: Option<usize>,
    /// Named output lanes. Empty for ordinary single-output nodes (and
    /// always empty for ingress nodes); only ops the registry marks
    /// `multi_output` may declare lanes. Serialised only when non-empty,
    /// so pre-lane spec JSON round-trips unchanged.
    pub lanes: Vec<SpecLane>,
}

impl SpecNode {
    /// The qualified reference consumers use for one of this node's lanes.
    pub fn lane_ref(&self, lane: &str) -> String {
        format!("{}.{}", self.id, lane)
    }
}

/// The ancestor cone of a set of spec outputs: which ingress nodes,
/// graph inputs and graph nodes must execute to produce them. The three
/// vectors are parallel to `spec.ingress` / `spec.graph_inputs` /
/// `spec.nodes` ([`GraphSpec::ancestor_cone`]).
///
/// This is the serving-side complement of the optimizer's
/// `DeadNodeElim`: DCE rewrites the spec once against *all* outputs,
/// the cone restricts one *request* to the subset its variant asked
/// for — without touching the spec.
#[derive(Debug, Clone, PartialEq)]
pub struct Cone {
    pub ingress: Vec<bool>,
    pub graph_inputs: Vec<bool>,
    pub nodes: Vec<bool>,
}

impl Cone {
    /// Count of (ingress, graph) nodes inside the cone.
    pub fn node_counts(&self) -> (usize, usize) {
        let alive = |v: &[bool]| v.iter().filter(|b| **b).count();
        (alive(&self.ingress), alive(&self.nodes))
    }
}

/// The exported preprocessing graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    pub name: String,
    pub inputs: Vec<SpecInput>,
    /// String-side ops run by the Rust ingress at serving time, in order.
    pub ingress: Vec<SpecNode>,
    /// Tensors the compiled graph takes, in positional order. Each is a
    /// column name that is either a numeric raw input or an ingress
    /// product (e.g. an auto-inserted `<col>__hash`).
    pub graph_inputs: Vec<String>,
    /// Numeric ops compiled to HLO, in topological (pipeline) order.
    pub nodes: Vec<SpecNode>,
    /// Columns the graph returns, in positional order.
    pub outputs: Vec<String>,
}

impl GraphSpec {
    /// Dtype+width of a graph input column (resolving through ingress).
    pub fn graph_input_meta(&self, name: &str) -> Option<(SpecDType, Option<usize>)> {
        if let Some(n) = self.ingress.iter().find(|n| n.id == name) {
            return Some((n.dtype, n.width));
        }
        self.inputs.iter().find(|i| i.name == name).map(|i| {
            (SpecDType::for_engine(&i.dtype), i.width)
        })
    }

    /// Meta of any graph-section column (input, node output, or lane).
    /// Lane values resolve both through their qualified `"id.lane"`
    /// reference and through their bare lane name. A multi-output
    /// node's *bare id* is not a value (the interpreter never binds it),
    /// so it deliberately does not resolve here.
    pub fn node_meta(&self, name: &str) -> Option<(SpecDType, Option<usize>)> {
        if let Some(n) = self.nodes.iter().find(|n| n.id == name && n.lanes.is_empty()) {
            return Some((n.dtype, n.width));
        }
        for n in self.nodes.iter().filter(|n| !n.lanes.is_empty()) {
            for l in &n.lanes {
                if l.name == name || name == n.lane_ref(&l.name) {
                    return Some((l.dtype, l.width));
                }
            }
        }
        self.graph_input_meta(name)
    }

    /// Compute the ancestor cone of a set of output names: the ingress
    /// nodes, graph inputs and graph nodes transitively required to
    /// produce them. Names may be anything a node input may be — node
    /// ids, bare lane names, qualified `"id.lane"` references, ingress
    /// products or raw inputs. Unknown names are simply absent from the
    /// cone (the interpreter will surface them as missing values when it
    /// actually needs them).
    ///
    /// Both sections are walked in reverse: `nodes` and `ingress` are
    /// stored in topological order, so one backward sweep per section
    /// settles transitive membership.
    pub fn ancestor_cone(&self, outputs: &[&str]) -> Cone {
        let mut needed: std::collections::HashSet<&str> = outputs.iter().copied().collect();
        let mut nodes = vec![false; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate().rev() {
            let wanted = if n.lanes.is_empty() {
                needed.contains(n.id.as_str())
            } else {
                // a multi-output node runs if ANY lane is consumed —
                // under either its bare name or its qualified reference
                n.lanes.iter().any(|l| {
                    needed.contains(l.name.as_str())
                        || needed.contains(n.lane_ref(&l.name).as_str())
                })
            };
            if wanted {
                nodes[i] = true;
                for input in &n.inputs {
                    needed.insert(input.as_str());
                }
            }
        }
        let graph_inputs: Vec<bool> = self
            .graph_inputs
            .iter()
            .map(|g| needed.contains(g.as_str()))
            .collect();
        let mut ingress = vec![false; self.ingress.len()];
        for (i, n) in self.ingress.iter().enumerate().rev() {
            if needed.contains(n.id.as_str()) {
                ingress[i] = true;
                for input in &n.inputs {
                    needed.insert(input.as_str());
                }
            }
        }
        Cone { ingress, graph_inputs, nodes }
    }

    /// [`Self::ancestor_cone`] over output *indices* into
    /// `self.outputs` (the shape serving request routing works in).
    pub fn ancestor_cone_of(&self, output_indices: &[usize]) -> Cone {
        let names: Vec<&str> = output_indices
            .iter()
            .filter_map(|&i| self.outputs.get(i).map(String::as_str))
            .collect();
        self.ancestor_cone(&names)
    }

    /// Variant names of a merged multi-variant spec, in first-appearance
    /// order — the distinct `"<variant>::"` prefixes of `outputs`
    /// ([`Self::merge_variants`] names every output that way). Empty for
    /// ordinary single-variant specs (any unprefixed output disqualifies
    /// the whole spec: a half-prefixed output list is not a variant
    /// contract anyone should route on).
    pub fn variants(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for o in &self.outputs {
            match o.split_once("::") {
                Some((v, _)) => {
                    if !out.contains(&v) {
                        out.push(v);
                    }
                }
                None => return Vec::new(),
            }
        }
        out
    }

    /// Output indices belonging to one variant of a merged spec, in
    /// output order (the order [`Self::merge_variants`] copied them in —
    /// identical to the variant's own `outputs` order).
    pub fn variant_outputs(&self, variant: &str) -> Vec<usize> {
        self.outputs
            .iter()
            .enumerate()
            .filter(|(_, o)| o.split_once("::").map(|(v, _)| v == variant).unwrap_or(false))
            .map(|(i, _)| i)
            .collect()
    }

    /// Merge K variant specs into one multi-variant spec evaluated in a
    /// single shared env — the serving shape for multi-variant models
    /// (K rankers sharing a preprocessing prefix). Inputs are unioned by
    /// name (conflicting dtype/width is an error); every variant-local
    /// ingress/node id is prefixed `"<variant>::"` so the sections
    /// concatenate without collisions; outputs are exposed as
    /// `"<variant>::<output>"` in variant order. The merged spec is
    /// correct but naive — run the optimizer (whose `CrossOutputDedup`
    /// pass exists for exactly this shape) to collapse the shared
    /// prefix to one evaluation.
    pub fn merge_variants(name: &str, variants: &[&GraphSpec]) -> Result<GraphSpec> {
        if variants.is_empty() {
            return Err(KamaeError::InvalidConfig(
                "merge_variants: no variant specs given".into(),
            ));
        }
        let mut inputs: Vec<SpecInput> = Vec::new();
        let mut seen_names: Vec<&str> = Vec::new();
        let mut ingress = Vec::new();
        let mut graph_inputs: Vec<String> = Vec::new();
        let mut nodes = Vec::new();
        let mut outputs = Vec::new();
        for v in variants {
            if seen_names.contains(&v.name.as_str()) {
                return Err(KamaeError::InvalidConfig(format!(
                    "duplicate variant name: {}",
                    v.name
                )));
            }
            seen_names.push(&v.name);
            for i in &v.inputs {
                match inputs.iter().find(|e| e.name == i.name) {
                    None => inputs.push(i.clone()),
                    Some(e) if e == i => {}
                    Some(e) => {
                        return Err(KamaeError::InvalidConfig(format!(
                            "variant {}: input {} conflicts with another variant's \
                             declaration ({:?}/width {:?} vs {:?}/width {:?})",
                            v.name, i.name, i.dtype, i.width, e.dtype, e.width
                        )))
                    }
                }
            }
            // variant-local producer names (raw inputs stay unprefixed)
            let local: std::collections::HashSet<&str> = v
                .ingress
                .iter()
                .chain(v.nodes.iter())
                .map(|n| n.id.as_str())
                .chain(v.nodes.iter().flat_map(|n| n.lanes.iter().map(|l| l.name.as_str())))
                .collect();
            let raw_inputs: std::collections::HashSet<&str> =
                v.inputs.iter().map(|i| i.name.as_str()).collect();
            let prefix = |r: &str| -> String {
                if local.contains(r) {
                    return format!("{}::{r}", v.name);
                }
                // lane reference: both halves are variant-local names
                // (the node id and the lane's bare column name). A raw
                // input whose own name contains a '.' is NOT a lane ref
                // even when its pre-dot segment matches a local id —
                // names are opaque keys everywhere else, so full-string
                // matches win over the split interpretation. The FIRST
                // dot splits: multi-output node ids are generated
                // dot-free (see MultiLaneBucketize), while lane names —
                // merged-away node ids, i.e. arbitrary column names —
                // may themselves contain dots.
                if !raw_inputs.contains(r) {
                    if let Some((head, lane)) = r.split_once('.') {
                        if local.contains(head) {
                            return format!("{0}::{head}.{0}::{lane}", v.name);
                        }
                    }
                }
                r.to_string()
            };
            for n in &v.ingress {
                let mut n = n.clone();
                n.id = format!("{}::{}", v.name, n.id);
                for i in n.inputs.iter_mut() {
                    *i = prefix(i);
                }
                ingress.push(n);
            }
            for g in &v.graph_inputs {
                let g = prefix(g);
                if !graph_inputs.contains(&g) {
                    graph_inputs.push(g);
                }
            }
            for n in &v.nodes {
                let mut n = n.clone();
                n.id = format!("{}::{}", v.name, n.id);
                for i in n.inputs.iter_mut() {
                    *i = prefix(i);
                }
                for l in n.lanes.iter_mut() {
                    l.name = format!("{}::{}", v.name, l.name);
                }
                nodes.push(n);
            }
            for o in &v.outputs {
                let r = prefix(o);
                if local.contains(o.as_str()) {
                    outputs.push(r);
                } else {
                    // pass-through output (a raw graph input): alias it
                    // under the variant-prefixed name so the merged
                    // output list has no cross-variant duplicates
                    let (dtype, width) = v.node_meta(o).ok_or_else(|| {
                        KamaeError::InvalidConfig(format!(
                            "variant {}: output {o} does not resolve",
                            v.name
                        ))
                    })?;
                    let id = format!("{}::{o}", v.name);
                    nodes.push(SpecNode {
                        id: id.clone(),
                        op: "identity".into(),
                        inputs: vec![r],
                        attrs: Json::object(),
                        dtype,
                        width,
                        lanes: vec![],
                    });
                    outputs.push(id);
                }
            }
        }
        Ok(GraphSpec {
            name: name.to_string(),
            inputs,
            ingress,
            graph_inputs,
            nodes,
            outputs,
        })
    }

    // ---- JSON ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("name", self.name.clone());
        j.set(
            "inputs",
            Json::Array(
                self.inputs
                    .iter()
                    .map(|i| {
                        let mut o = Json::object();
                        o.set("name", i.name.clone());
                        o.set("dtype", i.dtype.name());
                        match i.width {
                            Some(w) => o.set("width", w),
                            None => o.set("width", Json::Null),
                        };
                        o
                    })
                    .collect(),
            ),
        );
        j.set("ingress", Json::Array(self.ingress.iter().map(node_to_json).collect()));
        j.set(
            "graph_inputs",
            Json::Array(self.graph_inputs.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set("nodes", Json::Array(self.nodes.iter().map(node_to_json).collect()));
        j.set(
            "outputs",
            Json::Array(self.outputs.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j
    }

    pub fn from_json(j: &Json) -> Result<GraphSpec> {
        let inputs = j
            .req_array("inputs")?
            .iter()
            .map(|o| {
                Ok(SpecInput {
                    name: o.req_str("name")?.to_string(),
                    dtype: DType::parse(o.req_str("dtype")?)?,
                    width: o.opt_i64("width").map(|w| w as usize),
                })
            })
            .collect::<Result<_>>()?;
        let parse_nodes = |key: &str| -> Result<Vec<SpecNode>> {
            j.req_array(key)?.iter().map(node_from_json).collect()
        };
        Ok(GraphSpec {
            name: j.req_str("name")?.to_string(),
            inputs,
            ingress: parse_nodes("ingress")?,
            graph_inputs: j
                .req_array("graph_inputs")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| KamaeError::Serde("graph_inputs entry".into()))
                })
                .collect::<Result<_>>()?,
            nodes: parse_nodes("nodes")?,
            outputs: j
                .req_array("outputs")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| KamaeError::Serde("outputs entry".into()))
                })
                .collect::<Result<_>>()?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<GraphSpec> {
        let text = std::fs::read_to_string(path)?;
        GraphSpec::from_json(&Json::parse(&text)?)
    }
}

fn node_to_json(n: &SpecNode) -> Json {
    let mut o = Json::object();
    o.set("id", n.id.clone());
    o.set("op", n.op.clone());
    o.set(
        "inputs",
        Json::Array(n.inputs.iter().map(|s| Json::Str(s.clone())).collect()),
    );
    o.set("attrs", n.attrs.clone());
    o.set("dtype", n.dtype.name());
    match n.width {
        Some(w) => o.set("width", w),
        None => o.set("width", Json::Null),
    };
    // written only when present: single-output nodes keep the exact
    // pre-lane JSON shape (and old readers keep loading new specs that
    // never went through the multi-lane passes)
    if !n.lanes.is_empty() {
        o.set(
            "lanes",
            Json::Array(
                n.lanes
                    .iter()
                    .map(|l| {
                        let mut lo = Json::object();
                        lo.set("name", l.name.clone());
                        lo.set("attrs", l.attrs.clone());
                        lo.set("dtype", l.dtype.name());
                        match l.width {
                            Some(w) => lo.set("width", w),
                            None => lo.set("width", Json::Null),
                        };
                        lo
                    })
                    .collect(),
            ),
        );
    }
    o
}

fn node_from_json(j: &Json) -> Result<SpecNode> {
    // "lanes" is optional: pre-lane (PR ≤ 2) spec JSON has no such key
    // and must keep loading — backward compatibility is part of the
    // serving contract (old artifact specs are re-optimized at load).
    let lanes = match j.get("lanes") {
        None | Some(Json::Null) => Vec::new(),
        Some(l) => l
            .as_array()
            .ok_or_else(|| KamaeError::Serde("node lanes is not an array".into()))?
            .iter()
            .map(|lo| {
                Ok(SpecLane {
                    name: lo.req_str("name")?.to_string(),
                    attrs: lo.req("attrs")?.clone(),
                    dtype: SpecDType::parse(lo.req_str("dtype")?)?,
                    width: lo.opt_i64("width").map(|w| w as usize),
                })
            })
            .collect::<Result<_>>()?,
    };
    Ok(SpecNode {
        id: j.req_str("id")?.to_string(),
        op: j.req_str("op")?.to_string(),
        inputs: j
            .req_array("inputs")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| KamaeError::Serde("node input".into()))
            })
            .collect::<Result<_>>()?,
        attrs: j.req("attrs")?.clone(),
        dtype: SpecDType::parse(j.req_str("dtype")?)?,
        width: j.opt_i64("width").map(|w| w as usize),
        lanes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> GraphSpec {
        let mut attrs = Json::object();
        attrs.set("num_bins", 64i64);
        GraphSpec {
            name: "test".into(),
            inputs: vec![
                SpecInput { name: "UserID".into(), dtype: DType::Str, width: None },
                SpecInput { name: "price".into(), dtype: DType::F64, width: None },
            ],
            ingress: vec![SpecNode {
                id: "UserID__hash".into(),
                op: "hash64".into(),
                inputs: vec!["UserID".into()],
                attrs: Json::object(),
                dtype: SpecDType::I64,
                width: None,
                lanes: vec![],
            }],
            graph_inputs: vec!["UserID__hash".into(), "price".into()],
            nodes: vec![SpecNode {
                id: "UserID_indexed".into(),
                op: "hash_bucket".into(),
                inputs: vec!["UserID__hash".into()],
                attrs,
                dtype: SpecDType::I64,
                width: None,
                lanes: vec![],
            }],
            outputs: vec!["UserID_indexed".into(), "price".into()],
        }
    }

    /// A spec carrying a multi-output `multi_bucketize` node with one
    /// bucket lane and one compare lane.
    fn sample_with_lanes() -> GraphSpec {
        let mut attrs = Json::object();
        attrs.set("splits", Json::Array(vec![Json::Float(0.0), Json::Float(1.0)]));
        let mut bucket = Json::object();
        bucket.set("kind", "bucket");
        bucket.set("remap", Json::Array(vec![Json::Int(0), Json::Int(1), Json::Int(2)]));
        let mut cmp = Json::object();
        cmp.set("kind", "compare").set("op", "ge").set("value", 1.0);
        GraphSpec {
            name: "lanes".into(),
            inputs: vec![SpecInput { name: "price".into(), dtype: DType::F64, width: None }],
            ingress: vec![],
            graph_inputs: vec!["price".into()],
            nodes: vec![
                SpecNode {
                    id: "price__lanes".into(),
                    op: "multi_bucketize".into(),
                    inputs: vec!["price".into()],
                    attrs,
                    dtype: SpecDType::I64,
                    width: None,
                    lanes: vec![
                        SpecLane {
                            name: "price_bucket".into(),
                            attrs: bucket,
                            dtype: SpecDType::I64,
                            width: None,
                        },
                        SpecLane {
                            name: "is_pricey".into(),
                            attrs: cmp,
                            dtype: SpecDType::I64,
                            width: None,
                        },
                    ],
                },
                SpecNode {
                    id: "bucket_not".into(),
                    op: "not".into(),
                    inputs: vec!["price__lanes.is_pricey".into()],
                    attrs: Json::object(),
                    dtype: SpecDType::I64,
                    width: None,
                    lanes: vec![],
                },
            ],
            outputs: vec!["price_bucket".into(), "bucket_not".into()],
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = sample();
        let j = s.to_json();
        let back = GraphSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn meta_resolution() {
        let s = sample();
        assert_eq!(s.graph_input_meta("price"), Some((SpecDType::F32, None)));
        assert_eq!(s.graph_input_meta("UserID__hash"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("UserID_indexed"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("missing"), None);
    }

    #[test]
    fn lanes_json_roundtrip() {
        let s = sample_with_lanes();
        let j = s.to_json();
        let back = GraphSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
        // lane meta resolves through both the qualified ref and the bare name
        assert_eq!(s.node_meta("price__lanes.price_bucket"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("price_bucket"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("price__lanes.nope"), None);
    }

    #[test]
    fn single_output_nodes_serialise_without_a_lanes_key() {
        // the pre-lane JSON shape is preserved exactly for ordinary nodes
        let s = sample();
        let j = s.to_json();
        let node = &j.req_array("nodes").unwrap()[0];
        assert!(node.get("lanes").is_none());
    }

    #[test]
    fn pre_lane_spec_json_still_loads() {
        // a spec serialised before lanes existed (no "lanes" key anywhere)
        // must keep loading — old artifact files are re-optimized at
        // serving load time, not re-exported
        let text = r#"{
            "name": "legacy",
            "inputs": [{"name": "x", "dtype": "float64", "width": null}],
            "ingress": [],
            "graph_inputs": ["x"],
            "nodes": [{
                "id": "y", "op": "log1p", "inputs": ["x"],
                "attrs": {}, "dtype": "float32", "width": null
            }],
            "outputs": ["y"]
        }"#;
        let spec = GraphSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.nodes.len(), 1);
        assert!(spec.nodes[0].lanes.is_empty());
        // and it re-serialises into the same lane-free node shape
        let j = spec.to_json();
        assert!(j.req_array("nodes").unwrap()[0].get("lanes").is_none());
    }

    #[test]
    fn merge_variants_prefixes_and_unions() {
        let mut a = sample();
        a.name = "a".into();
        let mut b = sample();
        b.name = "b".into();
        let m = GraphSpec::merge_variants("ab", &[&a, &b]).unwrap();
        // inputs unioned by name, sections concatenated with prefixes
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.ingress.len(), 2);
        assert_eq!(m.ingress[0].id, "a::UserID__hash");
        // per variant: the indexed node plus an identity alias for the
        // pass-through "price" output
        assert_eq!(m.nodes.len(), 4);
        assert_eq!(m.nodes[1].id, "a::price");
        assert_eq!(m.nodes[1].op, "identity");
        assert_eq!(m.nodes[2].id, "b::UserID_indexed");
        assert_eq!(m.nodes[2].inputs, vec!["b::UserID__hash".to_string()]);
        // raw inputs stay unprefixed and dedupe in graph_inputs
        assert!(m.graph_inputs.contains(&"price".to_string()));
        assert_eq!(m.graph_inputs.iter().filter(|g| *g == "price").count(), 1);
        assert_eq!(
            m.outputs,
            vec!["a::UserID_indexed", "a::price", "b::UserID_indexed", "b::price"]
        );
        // duplicate variant names are rejected
        assert!(GraphSpec::merge_variants("aa", &[&a, &a]).is_err());
        // conflicting input declarations are rejected
        let mut c = sample();
        c.name = "c".into();
        c.inputs[1].dtype = DType::Str;
        assert!(GraphSpec::merge_variants("ac", &[&a, &c]).is_err());
    }

    #[test]
    fn merge_variants_keeps_dotted_raw_input_names_opaque() {
        // a raw input named "lead.days" alongside a local node "lead":
        // references to the raw column must NOT be parsed as a lane ref
        // of the "lead" node
        let mut a = sample();
        a.name = "a".into();
        a.inputs.push(SpecInput { name: "lead.days".into(), dtype: DType::F64, width: None });
        a.graph_inputs.push("lead.days".into());
        a.nodes.push(SpecNode {
            id: "lead".into(),
            op: "log1p".into(),
            inputs: vec!["lead.days".into()],
            attrs: Json::object(),
            dtype: SpecDType::F32,
            width: None,
            lanes: vec![],
        });
        a.nodes.push(SpecNode {
            id: "days_neg".into(),
            op: "neg".into(),
            inputs: vec!["lead.days".into()],
            attrs: Json::object(),
            dtype: SpecDType::F32,
            width: None,
            lanes: vec![],
        });
        a.outputs = vec!["lead".into(), "days_neg".into()];
        let m = GraphSpec::merge_variants("m", &[&a]).unwrap();
        // both consumers still reference the raw column verbatim
        for n in m.nodes.iter().filter(|n| n.op == "log1p" || n.op == "neg") {
            assert_eq!(n.inputs, vec!["lead.days".to_string()], "{}", n.id);
        }
        assert!(m.graph_inputs.contains(&"lead.days".to_string()));
    }

    #[test]
    fn ancestor_cone_walks_lanes_ingress_and_graph_inputs() {
        let s = sample();
        // full outputs: everything is in the cone
        let full = s.ancestor_cone(&["UserID_indexed", "price"]);
        assert_eq!(full.ingress, vec![true]);
        assert_eq!(full.graph_inputs, vec![true, true]);
        assert_eq!(full.nodes, vec![true]);
        // price only: the hash ingress and the indexed node drop out
        let lite = s.ancestor_cone(&["price"]);
        assert_eq!(lite.ingress, vec![false]);
        assert_eq!(lite.graph_inputs, vec![false, true]);
        assert_eq!(lite.nodes, vec![false]);
        assert_eq!(lite.node_counts(), (0, 0));
        // indices variant agrees with the name variant
        assert_eq!(s.ancestor_cone_of(&[1]), lite);
        assert_eq!(s.ancestor_cone_of(&[0, 1]), full);
        // unknown names are simply absent
        let none = s.ancestor_cone(&["nope"]);
        assert_eq!(none.node_counts(), (0, 0));

        // lane spec: consuming one lane (via its qualified ref through
        // the `not` consumer) pulls in the multi-output node
        let l = sample_with_lanes();
        let c = l.ancestor_cone(&["bucket_not"]);
        assert_eq!(c.nodes, vec![true, true]);
        // consuming only the bare-named bucket lane also pulls the node
        // but not the `not` consumer
        let c = l.ancestor_cone(&["price_bucket"]);
        assert_eq!(c.nodes, vec![true, false]);
        assert_eq!(c.graph_inputs, vec![true]);
    }

    #[test]
    fn variant_helpers_split_merged_outputs() {
        let mut a = sample();
        a.name = "a".into();
        let mut b = sample();
        b.name = "b".into();
        let m = GraphSpec::merge_variants("ab", &[&a, &b]).unwrap();
        assert_eq!(m.variants(), vec!["a", "b"]);
        assert_eq!(m.variant_outputs("a"), vec![0, 1]);
        assert_eq!(m.variant_outputs("b"), vec![2, 3]);
        assert!(m.variant_outputs("c").is_empty());
        // per-variant cone: variant b's outputs never need variant a's
        // nodes, and both share the unprefixed raw input
        let cone = m.ancestor_cone_of(&m.variant_outputs("b"));
        for (i, n) in m.nodes.iter().enumerate() {
            let is_b = n.id.starts_with("b::");
            assert_eq!(cone.nodes[i], is_b, "{}", n.id);
        }
        assert!(cone.graph_inputs[m.graph_inputs.iter().position(|g| g == "price").unwrap()]);
        // ordinary specs expose no variants
        assert!(sample().variants().is_empty());
    }

    #[test]
    fn merge_variants_rewrites_lane_references() {
        let mut a = sample_with_lanes();
        a.name = "a".into();
        let m = GraphSpec::merge_variants("m", &[&a]).unwrap();
        assert_eq!(m.nodes[0].id, "a::price__lanes");
        assert_eq!(m.nodes[0].lanes[0].name, "a::price_bucket");
        // the consumer's lane ref is rewritten on both halves
        assert_eq!(m.nodes[1].inputs, vec!["a::price__lanes.a::is_pricey".to_string()]);
        assert_eq!(m.outputs, vec!["a::price_bucket", "a::bucket_not"]);
        // lane meta still resolves in the merged spec
        assert_eq!(m.node_meta("a::price__lanes.a::is_pricey"), Some((SpecDType::I64, None)));
        assert_eq!(m.node_meta("a::price_bucket"), Some((SpecDType::I64, None)));
    }
}
