//! GraphSpec data model and JSON (de)serialisation.

use crate::dataframe::DType;
use crate::error::{KamaeError, Result};
use crate::util::json::Json;

/// Tensor dtype inside the compiled graph. The whole graph runs on two
/// dtypes: `F32` for continuous features, `I64` for indices/hashes/dates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecDType {
    F32,
    I64,
}

impl SpecDType {
    pub fn name(&self) -> &'static str {
        match self {
            SpecDType::F32 => "float32",
            SpecDType::I64 => "int64",
        }
    }

    pub fn parse(s: &str) -> Result<SpecDType> {
        match s {
            "float32" => Ok(SpecDType::F32),
            "int64" => Ok(SpecDType::I64),
            other => Err(KamaeError::Serde(format!("bad spec dtype: {other}"))),
        }
    }

    /// Graph dtype for an engine column dtype (strings hash to I64).
    pub fn for_engine(dt: &DType) -> SpecDType {
        match dt {
            DType::I32 | DType::I64 | DType::Bool | DType::Str => SpecDType::I64,
            DType::F32 | DType::F64 => SpecDType::F32,
            DType::List(inner) => SpecDType::for_engine(inner),
        }
    }
}

/// A raw feature the serving request supplies.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecInput {
    pub name: String,
    /// Engine dtype of the raw feature (may be `string`, `array<string>`…).
    pub dtype: DType,
    /// Fixed sequence width, `None` for scalars. List-typed inputs MUST
    /// declare a width — ragged data cannot cross into the compiled graph.
    pub width: Option<usize>,
}

/// One operation in the spec (ingress or graph section).
#[derive(Debug, Clone, PartialEq)]
pub struct SpecNode {
    /// Output column name (ids and column names share one namespace).
    pub id: String,
    /// Op name — the contract with `python/compile/model.py::OPS` and
    /// [`super::interp`].
    pub op: String,
    /// Input column names.
    pub inputs: Vec<String>,
    /// Scalar attributes (and constants such as vocab hashes — kept in
    /// `attrs` as JSON arrays; i64 precision is preserved by our JSON).
    pub attrs: Json,
    /// Output dtype in the graph (`F32`/`I64`); for ingress nodes this is
    /// the *engine* view's graph projection once hashed.
    pub dtype: SpecDType,
    /// Output sequence width (`None` = scalar).
    pub width: Option<usize>,
}

/// The exported preprocessing graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    pub name: String,
    pub inputs: Vec<SpecInput>,
    /// String-side ops run by the Rust ingress at serving time, in order.
    pub ingress: Vec<SpecNode>,
    /// Tensors the compiled graph takes, in positional order. Each is a
    /// column name that is either a numeric raw input or an ingress
    /// product (e.g. an auto-inserted `<col>__hash`).
    pub graph_inputs: Vec<String>,
    /// Numeric ops compiled to HLO, in topological (pipeline) order.
    pub nodes: Vec<SpecNode>,
    /// Columns the graph returns, in positional order.
    pub outputs: Vec<String>,
}

impl GraphSpec {
    /// Dtype+width of a graph input column (resolving through ingress).
    pub fn graph_input_meta(&self, name: &str) -> Option<(SpecDType, Option<usize>)> {
        if let Some(n) = self.ingress.iter().find(|n| n.id == name) {
            return Some((n.dtype, n.width));
        }
        self.inputs.iter().find(|i| i.name == name).map(|i| {
            (SpecDType::for_engine(&i.dtype), i.width)
        })
    }

    /// Meta of any graph-section column (input or node output).
    pub fn node_meta(&self, name: &str) -> Option<(SpecDType, Option<usize>)> {
        if let Some(n) = self.nodes.iter().find(|n| n.id == name) {
            return Some((n.dtype, n.width));
        }
        self.graph_input_meta(name)
    }

    // ---- JSON ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("name", self.name.clone());
        j.set(
            "inputs",
            Json::Array(
                self.inputs
                    .iter()
                    .map(|i| {
                        let mut o = Json::object();
                        o.set("name", i.name.clone());
                        o.set("dtype", i.dtype.name());
                        match i.width {
                            Some(w) => o.set("width", w),
                            None => o.set("width", Json::Null),
                        };
                        o
                    })
                    .collect(),
            ),
        );
        j.set("ingress", Json::Array(self.ingress.iter().map(node_to_json).collect()));
        j.set(
            "graph_inputs",
            Json::Array(self.graph_inputs.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set("nodes", Json::Array(self.nodes.iter().map(node_to_json).collect()));
        j.set(
            "outputs",
            Json::Array(self.outputs.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j
    }

    pub fn from_json(j: &Json) -> Result<GraphSpec> {
        let inputs = j
            .req_array("inputs")?
            .iter()
            .map(|o| {
                Ok(SpecInput {
                    name: o.req_str("name")?.to_string(),
                    dtype: DType::parse(o.req_str("dtype")?)?,
                    width: o.opt_i64("width").map(|w| w as usize),
                })
            })
            .collect::<Result<_>>()?;
        let parse_nodes = |key: &str| -> Result<Vec<SpecNode>> {
            j.req_array(key)?.iter().map(node_from_json).collect()
        };
        Ok(GraphSpec {
            name: j.req_str("name")?.to_string(),
            inputs,
            ingress: parse_nodes("ingress")?,
            graph_inputs: j
                .req_array("graph_inputs")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| KamaeError::Serde("graph_inputs entry".into()))
                })
                .collect::<Result<_>>()?,
            nodes: parse_nodes("nodes")?,
            outputs: j
                .req_array("outputs")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| KamaeError::Serde("outputs entry".into()))
                })
                .collect::<Result<_>>()?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<GraphSpec> {
        let text = std::fs::read_to_string(path)?;
        GraphSpec::from_json(&Json::parse(&text)?)
    }
}

fn node_to_json(n: &SpecNode) -> Json {
    let mut o = Json::object();
    o.set("id", n.id.clone());
    o.set("op", n.op.clone());
    o.set(
        "inputs",
        Json::Array(n.inputs.iter().map(|s| Json::Str(s.clone())).collect()),
    );
    o.set("attrs", n.attrs.clone());
    o.set("dtype", n.dtype.name());
    match n.width {
        Some(w) => o.set("width", w),
        None => o.set("width", Json::Null),
    };
    o
}

fn node_from_json(j: &Json) -> Result<SpecNode> {
    Ok(SpecNode {
        id: j.req_str("id")?.to_string(),
        op: j.req_str("op")?.to_string(),
        inputs: j
            .req_array("inputs")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| KamaeError::Serde("node input".into()))
            })
            .collect::<Result<_>>()?,
        attrs: j.req("attrs")?.clone(),
        dtype: SpecDType::parse(j.req_str("dtype")?)?,
        width: j.opt_i64("width").map(|w| w as usize),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> GraphSpec {
        let mut attrs = Json::object();
        attrs.set("num_bins", 64i64);
        GraphSpec {
            name: "test".into(),
            inputs: vec![
                SpecInput { name: "UserID".into(), dtype: DType::Str, width: None },
                SpecInput { name: "price".into(), dtype: DType::F64, width: None },
            ],
            ingress: vec![SpecNode {
                id: "UserID__hash".into(),
                op: "hash64".into(),
                inputs: vec!["UserID".into()],
                attrs: Json::object(),
                dtype: SpecDType::I64,
                width: None,
            }],
            graph_inputs: vec!["UserID__hash".into(), "price".into()],
            nodes: vec![SpecNode {
                id: "UserID_indexed".into(),
                op: "hash_bucket".into(),
                inputs: vec!["UserID__hash".into()],
                attrs,
                dtype: SpecDType::I64,
                width: None,
            }],
            outputs: vec!["UserID_indexed".into(), "price".into()],
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = sample();
        let j = s.to_json();
        let back = GraphSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn meta_resolution() {
        let s = sample();
        assert_eq!(s.graph_input_meta("price"), Some((SpecDType::F32, None)));
        assert_eq!(s.graph_input_meta("UserID__hash"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("UserID_indexed"), Some((SpecDType::I64, None)));
        assert_eq!(s.node_meta("missing"), None);
    }
}
