//! SpecBuilder — assembles a [`GraphSpec`] while a fitted pipeline walks
//! its stages.
//!
//! The builder owns the ingress/graph split: transformers just declare
//! "this op is a string op" (`ingress_node`) or "this op is numeric"
//! (`graph_node`) and the builder
//!
//! * auto-inserts `hash64` ingress nodes when a string column flows into
//!   the numeric graph (the string→token-hash boundary, DESIGN.md
//!   §Substitutions),
//! * registers raw-numeric / ingress-produced columns as positional graph
//!   inputs exactly once,
//! * rejects ill-formed flows (string op consuming a graph product,
//!   ragged lists entering the graph, unknown columns).
//!
//! The builder only ever emits single-output nodes (`lanes` stays
//! empty): multi-output nodes ([`crate::export::SpecLane`]) are an
//! optimizer product, created when `optim::passes::MultiLaneBucketize`
//! merges sibling nodes after export.

use std::collections::HashMap;

use crate::dataframe::DType;
use crate::error::{KamaeError, Result};
use crate::optim::names as op_names;
use crate::util::json::Json;

use super::spec::{GraphSpec, SpecDType, SpecInput, SpecNode};

/// Where a column lives during spec construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Side {
    /// Raw request feature (numeric or string).
    Raw,
    /// Produced by an ingress node.
    Ingress,
    /// Produced by a compiled-graph node.
    Graph,
}

#[derive(Debug, Clone)]
struct ColMeta {
    side: Side,
    /// Engine-level dtype (strings distinguishable from numerics).
    engine_dtype: DType,
    width: Option<usize>,
}

/// Builder for [`GraphSpec`]. Created by
/// [`crate::pipeline::PipelineModel::to_graph_spec`].
pub struct SpecBuilder {
    name: String,
    inputs: Vec<SpecInput>,
    cols: HashMap<String, ColMeta>,
    ingress: Vec<SpecNode>,
    nodes: Vec<SpecNode>,
    graph_inputs: Vec<String>,
}

impl SpecBuilder {
    /// Start a spec from the serving input schema.
    pub fn new(name: &str, inputs: Vec<SpecInput>) -> Result<SpecBuilder> {
        let mut cols = HashMap::new();
        for i in &inputs {
            if matches!(i.dtype, DType::List(_)) && i.width.is_none() {
                return Err(KamaeError::InvalidConfig(format!(
                    "list-typed input {} must declare a fixed width",
                    i.name
                )));
            }
            cols.insert(
                i.name.clone(),
                ColMeta { side: Side::Raw, engine_dtype: i.dtype.clone(), width: i.width },
            );
        }
        Ok(SpecBuilder {
            name: name.to_string(),
            inputs,
            cols,
            ingress: vec![],
            nodes: vec![],
            graph_inputs: vec![],
        })
    }

    /// Engine dtype of a known column.
    pub fn engine_dtype(&self, col: &str) -> Result<&DType> {
        self.cols
            .get(col)
            .map(|m| &m.engine_dtype)
            .ok_or_else(|| KamaeError::ColumnNotFound(format!("{col} (in spec builder)")))
    }

    /// Width of a known column (None = scalar).
    pub fn width(&self, col: &str) -> Result<Option<usize>> {
        self.cols
            .get(col)
            .map(|m| m.width)
            .ok_or_else(|| KamaeError::ColumnNotFound(format!("{col} (in spec builder)")))
    }

    /// Whether the column is string-typed at the engine level.
    pub fn is_string(&self, col: &str) -> Result<bool> {
        let dt = self.engine_dtype(col)?;
        Ok(matches!(dt, DType::Str)
            || matches!(dt, DType::List(inner) if matches!(**inner, DType::Str)))
    }

    /// Add a string-side op. Inputs must not be graph products.
    pub fn ingress_node(
        &mut self,
        op: &str,
        inputs: &[&str],
        attrs: Json,
        out: &str,
        out_dtype: DType,
        out_width: Option<usize>,
    ) -> Result<()> {
        for &i in inputs {
            let meta = self
                .cols
                .get(i)
                .ok_or_else(|| KamaeError::ColumnNotFound(format!("{i} (ingress input)")))?;
            if meta.side == Side::Graph {
                return Err(KamaeError::Unsupported(format!(
                    "string op '{op}' consumes graph-computed column {i}; string \
                     transformations must precede numeric ones in exported pipelines"
                )));
            }
        }
        self.ingress.push(SpecNode {
            id: out.to_string(),
            op: op.to_string(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            attrs,
            dtype: SpecDType::for_engine(&out_dtype),
            width: out_width,
            lanes: vec![],
        });
        self.cols.insert(
            out.to_string(),
            ColMeta { side: Side::Ingress, engine_dtype: out_dtype, width: out_width },
        );
        Ok(())
    }

    /// Add a compiled-graph op. String inputs are auto-hashed; numeric
    /// raw/ingress inputs are registered as graph inputs. Returns the
    /// resolved graph-side input names in order.
    pub fn graph_node(
        &mut self,
        op: &str,
        inputs: &[&str],
        attrs: Json,
        out: &str,
        out_dtype: SpecDType,
        out_width: Option<usize>,
    ) -> Result<Vec<String>> {
        let resolved: Vec<String> = inputs
            .iter()
            .map(|&i| self.graph_ref(i))
            .collect::<Result<_>>()?;
        self.nodes.push(SpecNode {
            id: out.to_string(),
            op: op.to_string(),
            inputs: resolved.clone(),
            attrs,
            dtype: out_dtype,
            width: out_width,
            lanes: vec![],
        });
        let engine_dtype = match out_dtype {
            SpecDType::F32 => DType::F64, // engine computes f64
            SpecDType::I64 => DType::I64,
        };
        let engine_dtype = if out_width.is_some() {
            DType::List(Box::new(engine_dtype))
        } else {
            engine_dtype
        };
        self.cols.insert(
            out.to_string(),
            ColMeta { side: Side::Graph, engine_dtype, width: out_width },
        );
        Ok(resolved)
    }

    /// Resolve a column to its graph-side name, inserting `hash64` ingress
    /// nodes and registering graph inputs as needed.
    pub fn graph_ref(&mut self, col: &str) -> Result<String> {
        let meta = self
            .cols
            .get(col)
            .cloned()
            .ok_or_else(|| KamaeError::ColumnNotFound(format!("{col} (graph input)")))?;
        let is_string = matches!(meta.engine_dtype, DType::Str)
            || matches!(&meta.engine_dtype, DType::List(i) if matches!(**i, DType::Str));
        match meta.side {
            Side::Graph => Ok(col.to_string()),
            Side::Raw | Side::Ingress => {
                if is_string {
                    if meta.width.is_none() && matches!(meta.engine_dtype, DType::List(_)) {
                        return Err(KamaeError::InvalidConfig(format!(
                            "ragged list column {col} cannot enter the compiled graph; \
                             pad it to a fixed length first"
                        )));
                    }
                    let hashed = format!("{col}__hash");
                    if !self.cols.contains_key(&hashed) {
                        let out_dtype = if matches!(meta.engine_dtype, DType::List(_)) {
                            DType::List(Box::new(DType::I64))
                        } else {
                            DType::I64
                        };
                        self.ingress_node(
                            op_names::HASH64,
                            &[col],
                            Json::object(),
                            &hashed,
                            out_dtype,
                            meta.width,
                        )?;
                    }
                    self.register_graph_input(&hashed);
                    Ok(hashed)
                } else {
                    if meta.width.is_none() && matches!(meta.engine_dtype, DType::List(_)) {
                        return Err(KamaeError::InvalidConfig(format!(
                            "ragged list column {col} cannot enter the compiled graph; \
                             pad it to a fixed length first"
                        )));
                    }
                    self.register_graph_input(col);
                    Ok(col.to_string())
                }
            }
        }
    }

    fn register_graph_input(&mut self, col: &str) {
        if !self.graph_inputs.iter().any(|g| g == col) {
            self.graph_inputs.push(col.to_string());
        }
    }

    /// Finalise the spec with the requested output columns. Every output
    /// must be graph-side (numeric) — string outputs cannot cross the HLO
    /// boundary and should stay engine-side.
    pub fn finish(mut self, outputs: &[&str]) -> Result<GraphSpec> {
        let mut outs = Vec::with_capacity(outputs.len());
        for &o in outputs {
            // pass-through outputs (raw numerics / ingress products) get a
            // graph identity node so the compiled function returns them.
            let meta = self
                .cols
                .get(o)
                .cloned()
                .ok_or_else(|| KamaeError::ColumnNotFound(format!("{o} (spec output)")))?;
            match meta.side {
                Side::Graph => outs.push(o.to_string()),
                _ => {
                    let gref = self.graph_ref(o)?;
                    let (dtype, width) = (
                        SpecDType::for_engine(&meta.engine_dtype),
                        meta.width,
                    );
                    let id = format!("{o}__out");
                    self.nodes.push(SpecNode {
                        id: id.clone(),
                        op: op_names::IDENTITY.into(),
                        inputs: vec![gref],
                        attrs: Json::object(),
                        dtype,
                        width,
                        lanes: vec![],
                    });
                    outs.push(id);
                }
            }
        }
        Ok(GraphSpec {
            name: self.name,
            inputs: self.inputs,
            ingress: self.ingress,
            graph_inputs: self.graph_inputs,
            nodes: self.nodes,
            outputs: outs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> Vec<SpecInput> {
        vec![
            SpecInput { name: "city".into(), dtype: DType::Str, width: None },
            SpecInput { name: "price".into(), dtype: DType::F64, width: None },
            SpecInput {
                name: "amenities".into(),
                dtype: DType::List(Box::new(DType::Str)),
                width: Some(4),
            },
        ]
    }

    #[test]
    fn auto_hash_on_string_input() {
        let mut b = SpecBuilder::new("t", inputs()).unwrap();
        let mut attrs = Json::object();
        attrs.set("num_bins", 32i64);
        b.graph_node("hash_bucket", &["city"], attrs, "city_idx", SpecDType::I64, None)
            .unwrap();
        let spec = b.finish(&["city_idx"]).unwrap();
        assert_eq!(spec.ingress.len(), 1);
        assert_eq!(spec.ingress[0].op, "hash64");
        assert_eq!(spec.graph_inputs, vec!["city__hash".to_string()]);
        assert_eq!(spec.nodes[0].inputs, vec!["city__hash".to_string()]);
    }

    #[test]
    fn pass_through_output_gets_identity() {
        let b = SpecBuilder::new("t", inputs()).unwrap();
        let spec = b.finish(&["price"]).unwrap();
        assert_eq!(spec.nodes.len(), 1);
        assert_eq!(spec.nodes[0].op, "identity");
        assert_eq!(spec.outputs, vec!["price__out".to_string()]);
        assert_eq!(spec.graph_inputs, vec!["price".to_string()]);
    }

    #[test]
    fn string_op_after_graph_rejected() {
        let mut b = SpecBuilder::new("t", inputs()).unwrap();
        b.graph_node("log1p", &["price"], Json::object(), "lp", SpecDType::F32, None)
            .unwrap();
        let err = b.ingress_node("upper", &["lp"], Json::object(), "u", DType::Str, None);
        assert!(err.is_err());
    }

    #[test]
    fn list_string_hashes_with_width() {
        let mut b = SpecBuilder::new("t", inputs()).unwrap();
        let mut attrs = Json::object();
        attrs.set("num_bins", 8i64);
        b.graph_node("hash_bucket", &["amenities"], attrs, "am_idx", SpecDType::I64, Some(4))
            .unwrap();
        let spec = b.finish(&["am_idx"]).unwrap();
        assert_eq!(spec.ingress[0].width, Some(4));
        assert_eq!(spec.nodes[0].width, Some(4));
    }

    #[test]
    fn dedup_graph_inputs() {
        let mut b = SpecBuilder::new("t", inputs()).unwrap();
        b.graph_node("log1p", &["price"], Json::object(), "a", SpecDType::F32, None).unwrap();
        b.graph_node("exp", &["price"], Json::object(), "b", SpecDType::F32, None).unwrap();
        let spec = b.finish(&["a", "b"]).unwrap();
        assert_eq!(spec.graph_inputs, vec!["price".to_string()]);
    }

    #[test]
    fn unknown_column_errors() {
        let mut b = SpecBuilder::new("t", inputs()).unwrap();
        assert!(b
            .graph_node("log1p", &["nope"], Json::object(), "x", SpecDType::F32, None)
            .is_err());
    }
}
