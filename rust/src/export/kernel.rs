//! Columnar kernel programs — the compiled form of a [`GraphSpec`].
//!
//! [`KernelProgram::compile`] runs ONCE at backend-load time and turns a
//! spec into a topologically ordered `Vec<Kernel>` of typed enum
//! variants with every attribute pre-parsed (splits materialised,
//! regexes compiled, affine step tables built, vocab arrays decoded)
//! and every input/output resolved to a dense **slot index** into a
//! flat buffer arena. The per-batch hot path then does no op-name
//! string matching, no attr JSON lookups and no `HashMap` env walk —
//! each kernel is a batch-at-a-time columnar loop over dense
//! `Vec<f64>` / `Vec<i64>` buffers ([`KVal`]) written as iterator
//! chains the compiler can auto-vectorize.
//!
//! **Bit-exactness contract:** every kernel body replicates the
//! matching `eval_node` / `eval_multi` arm in `interp.rs` expression
//! for expression — including the `as f32 as f64` intermediate
//! rounding the compiled graphs use — so the kernel path, the
//! interpreted oracle and the compiled artifact agree bit for bit
//! (pinned by the differential property in `tests/properties.rs` and
//! the `benches/kernel_program.rs` gate).
//!
//! **Fallback contract:** compilation is best-effort. Any spec shape
//! the compiler does not understand (unknown op, malformed attrs, a
//! regex that fails to compile, duplicate bindings) makes
//! `compile` return an error and [`super::SpecInterpreter`] silently
//! keeps `program: None`, serving through the original `eval_node`
//! oracle — request-time behaviour (including error messages) is
//! preserved exactly.
//!
//! **Null bitmask:** graph values carry an explicit per-row null mask
//! captured from the input [`Column`]s (the shape
//! `dataframe/column.rs` already uses). Masks are advisory metadata —
//! values flow exactly as in the oracle, which ignores engine nulls —
//! propagated as the union of the argument masks; `impute` is the one
//! op that *defines* missing values, so it clears the mask.

use std::borrow::Cow;
use std::collections::HashMap;

use crate::dataframe::{union_null_masks, Column, DataFrame, DType};
use crate::error::{KamaeError, Result};
use crate::ops;
use crate::ops::logical::CmpOp;
use crate::ops::math::{BinOp, UnaryOp};
use crate::runtime::{Tensor, TensorData};
use crate::util::json::Json;

use super::interp::{
    attr_f64_array, attr_i64_array, fixed_width, parse_fused_chain, run_fused_walk, StrStep,
};
use super::spec::{GraphSpec, SpecNode};
use super::RouteGroup;

// ---------------------------------------------------------------------------
// values

/// Dense columnar buffer: the kernel-program analogue of `GVal`.
#[derive(Debug, Clone)]
pub(crate) enum KBuf {
    F(Vec<f64>),
    I(Vec<i64>),
}

/// One graph value in the arena: a flat rows × width buffer plus an
/// explicit per-row null mask (advisory — see module docs).
#[derive(Debug, Clone)]
pub(crate) struct KVal {
    buf: KBuf,
    width: Option<usize>,
    nulls: Option<Vec<bool>>,
}

impl KVal {
    fn rows(&self) -> usize {
        let w = self.width.unwrap_or(1);
        match &self.buf {
            KBuf::F(v) => v.len() / w,
            KBuf::I(v) => v.len() / w,
        }
    }

    /// Float view: borrows when already `F`, converts like `GVal::as_f`
    /// otherwise (`i64 as f64`).
    fn as_f(&self) -> Cow<'_, [f64]> {
        match &self.buf {
            KBuf::F(v) => Cow::Borrowed(v.as_slice()),
            KBuf::I(v) => Cow::Owned(v.iter().map(|&x| x as f64).collect()),
        }
    }

    /// Int view: borrows when already `I`, converts like `GVal::as_i`
    /// otherwise (`f64 as i64`).
    fn as_i(&self) -> Cow<'_, [i64]> {
        match &self.buf {
            KBuf::I(v) => Cow::Borrowed(v.as_slice()),
            KBuf::F(v) => Cow::Owned(v.iter().map(|&x| x as i64).collect()),
        }
    }

    /// Copy out a contiguous row range — bit-identical to
    /// `GVal::slice_rows`; the null mask slices row-wise.
    fn slice_rows(&self, start: usize, len: usize) -> KVal {
        let w = self.width.unwrap_or(1);
        let buf = match &self.buf {
            KBuf::F(v) => KBuf::F(v[start * w..(start + len) * w].to_vec()),
            KBuf::I(v) => KBuf::I(v[start * w..(start + len) * w].to_vec()),
        };
        KVal {
            buf,
            width: self.width,
            nulls: self.nulls.as_ref().map(|n| n[start..start + len].to_vec()),
        }
    }

    /// Marshal to a serving tensor — same dtype/shape rules as
    /// `GVal::to_tensor` (floats leave as f32; the mask is dropped).
    fn to_tensor(&self, batch: usize) -> Tensor {
        let shape = match self.width {
            Some(w) => vec![batch, w],
            None => vec![batch],
        };
        match &self.buf {
            KBuf::F(v) => Tensor {
                data: TensorData::F32(v.iter().map(|&x| x as f32).collect()),
                shape,
            },
            KBuf::I(v) => Tensor { data: TensorData::I64(v.clone()), shape },
        }
    }

    /// Bind a request column — `column_to_gval` semantics plus null
    /// capture (list columns have no mask at the column layer).
    fn from_column(col: &Column) -> Result<KVal> {
        let scalar = |buf: KBuf, nulls: &Option<Vec<bool>>| KVal {
            buf,
            width: None,
            nulls: nulls.clone(),
        };
        let list = |buf: KBuf, w: usize| KVal { buf, width: Some(w), nulls: None };
        Ok(match col {
            Column::Bool(v, n) => scalar(KBuf::I(v.iter().map(|&b| b as i64).collect()), n),
            Column::I32(v, n) => scalar(KBuf::I(v.iter().map(|&x| x as i64).collect()), n),
            Column::I64(v, n) => scalar(KBuf::I(v.clone()), n),
            Column::F32(v, n) => scalar(KBuf::F(v.iter().map(|&x| x as f64).collect()), n),
            Column::F64(v, n) => scalar(KBuf::F(v.clone()), n),
            Column::ListBool(l) => list(
                KBuf::I(l.values.iter().map(|&b| b as i64).collect()),
                fixed_width(&l.offsets, "bool list")?,
            ),
            Column::ListI32(l) => list(
                KBuf::I(l.values.iter().map(|&x| x as i64).collect()),
                fixed_width(&l.offsets, "int32 list")?,
            ),
            Column::ListI64(l) => list(
                KBuf::I(l.values.clone()),
                fixed_width(&l.offsets, "int64 list")?,
            ),
            Column::ListF32(l) => list(
                KBuf::F(l.values.iter().map(|&x| x as f64).collect()),
                fixed_width(&l.offsets, "float32 list")?,
            ),
            Column::ListF64(l) => list(
                KBuf::F(l.values.clone()),
                fixed_width(&l.offsets, "float64 list")?,
            ),
            Column::Str(..) | Column::ListStr(_) => {
                return Err(KamaeError::Unsupported(
                    "string column crossing into graph section (missing hash64?)".into(),
                ))
            }
        })
    }
}

// ---------------------------------------------------------------------------
// ingress kernels

/// One pre-parsed ingress op. Bodies call the exact engine kernels
/// `ingress_op_column` dispatches to — only the per-batch string match
/// and attr parsing are gone; regexes are compiled at program build.
enum IngressStep {
    Hash64,
    Case(ops::string_ops::CaseMode),
    Trim,
    Substring { start: usize, len: usize },
    Replace { from: String, to: String },
    RegexReplace { re: ops::regex::Regex, rep: String },
    RegexExtract { re: ops::regex::Regex, group: usize },
    Concat { separator: String },
    SplitPad { separator: String, list_length: usize, default: String },
    Join { separator: String },
    StringMatch { needle: String, mode: ops::string_ops::MatchMode },
    StrLen,
    DateToDays,
    TimestampToSeconds,
    ElementAt { index: i64 },
    SliceList { start: usize, len: usize },
    PadList { len: usize, default: String },
    ToString,
    ParseNumber,
    /// Fused chain: the per-value string walk when the chain qualifies
    /// (parsed once), else step replay over pre-parsed sub-steps.
    Fused { walk: Option<(Vec<StrStep>, bool)>, replay: Vec<IngressStep> },
}

impl IngressStep {
    fn compile(op: &str, a: &Json) -> Result<IngressStep> {
        use ops::string_ops::{CaseMode, MatchMode};
        Ok(match op {
            "hash64" => IngressStep::Hash64,
            "case" => IngressStep::Case(match a.req_str("mode")? {
                "upper" => CaseMode::Upper,
                "lower" => CaseMode::Lower,
                _ => CaseMode::Title,
            }),
            "trim" => IngressStep::Trim,
            "substring" => IngressStep::Substring {
                start: a.req_i64("start")? as usize,
                len: a.req_i64("len")? as usize,
            },
            "replace" => IngressStep::Replace {
                from: a.req_str("from")?.to_string(),
                to: a.req_str("to")?.to_string(),
            },
            "regex_replace" => IngressStep::RegexReplace {
                re: ops::regex::Regex::new(a.req_str("pattern")?)?,
                rep: a.req_str("rep")?.to_string(),
            },
            "regex_extract" => IngressStep::RegexExtract {
                re: ops::regex::Regex::new(a.req_str("pattern")?)?,
                group: a.req_i64("group")? as usize,
            },
            "concat" => IngressStep::Concat {
                separator: a.req_str("separator")?.to_string(),
            },
            "split_pad" => IngressStep::SplitPad {
                separator: a.req_str("separator")?.to_string(),
                list_length: a.req_i64("list_length")? as usize,
                default: a.req_str("default")?.to_string(),
            },
            "join" => IngressStep::Join {
                separator: a.req_str("separator")?.to_string(),
            },
            "string_match" => IngressStep::StringMatch {
                needle: a.req_str("needle")?.to_string(),
                mode: match a.req_str("mode")? {
                    "starts_with" => MatchMode::StartsWith,
                    "ends_with" => MatchMode::EndsWith,
                    _ => MatchMode::Contains,
                },
            },
            "str_len" => IngressStep::StrLen,
            "date_to_days" => IngressStep::DateToDays,
            "timestamp_to_seconds" => IngressStep::TimestampToSeconds,
            "element_at" => IngressStep::ElementAt { index: a.req_i64("index")? },
            "slice_list" => IngressStep::SliceList {
                start: a.req_i64("start")? as usize,
                len: a.req_i64("len")? as usize,
            },
            "pad_list" => IngressStep::PadList {
                len: a.req_i64("len")? as usize,
                default: a.req_str("default")?.to_string(),
            },
            "to_string" => IngressStep::ToString,
            "parse_number" => IngressStep::ParseNumber,
            "fused_ingress" => {
                let steps = a.req_array("steps")?;
                let walk = parse_fused_chain(steps)?;
                let replay = steps
                    .iter()
                    .map(|s| IngressStep::compile(s.req_str("op")?, s))
                    .collect::<Result<Vec<_>>>()?;
                IngressStep::Fused { walk, replay }
            }
            other => return Err(KamaeError::Unsupported(format!("ingress op: {other}"))),
        })
    }

    fn run(&self, cols: &[&Column]) -> Result<Column> {
        use ops::string_ops as so;
        let input = |i: usize| -> Result<&Column> {
            cols.get(i).copied().ok_or_else(|| {
                KamaeError::InvalidConfig(format!("ingress kernel: missing input {i}"))
            })
        };
        Ok(match self {
            IngressStep::Hash64 => ops::hash::hash64_column(input(0)?)?,
            IngressStep::Case(mode) => so::change_case(input(0)?, *mode)?,
            IngressStep::Trim => so::trim(input(0)?)?,
            IngressStep::Substring { start, len } => so::substring(input(0)?, *start, *len)?,
            IngressStep::Replace { from, to } => so::replace_literal(input(0)?, from, to)?,
            IngressStep::RegexReplace { re, rep } => {
                ops::regex::regex_replace(input(0)?, re, rep)?
            }
            IngressStep::RegexExtract { re, group } => {
                ops::regex::regex_extract(input(0)?, re, *group)?
            }
            IngressStep::Concat { separator } => so::concat_cols(cols, separator)?,
            IngressStep::SplitPad { separator, list_length, default } => {
                let split = so::split(input(0)?, separator)?;
                so::pad_list(&split, *list_length, default)?
            }
            IngressStep::Join { separator } => {
                let l = input(0)?.as_list_str()?;
                Column::from_str(l.rows().map(|r| r.join(separator)).collect::<Vec<String>>())
            }
            IngressStep::StringMatch { needle, mode } => {
                so::string_match(input(0)?, needle, *mode)?
            }
            IngressStep::StrLen => so::str_len(input(0)?)?,
            IngressStep::DateToDays => ops::date::date_to_days(input(0)?)?,
            IngressStep::TimestampToSeconds => ops::date::timestamp_to_seconds(input(0)?)?,
            IngressStep::ElementAt { index } => ops::array::element_at(input(0)?, *index)?,
            IngressStep::SliceList { start, len } => {
                ops::array::slice_list(input(0)?, *start, *len)?
            }
            IngressStep::PadList { len, default } => so::pad_list(input(0)?, *len, default)?,
            IngressStep::ToString => ops::cast::cast(input(0)?, &DType::Str)?,
            IngressStep::ParseNumber => ops::cast::cast(input(0)?, &DType::F64)?,
            IngressStep::Fused { walk, replay } => {
                if let Some((chain, hash_tail)) = walk {
                    if let Some(out) = run_fused_walk(chain, *hash_tail, input(0)?) {
                        return Ok(out);
                    }
                }
                let mut col = input(0)?.clone();
                for s in replay {
                    col = s.run(&[&col])?;
                }
                col
            }
        })
    }
}

/// One ingress node with its inputs and output column id resolved.
struct IngressKernel {
    id: String,
    inputs: Vec<String>,
    step: IngressStep,
}

impl IngressKernel {
    fn compile(node: &SpecNode) -> Result<IngressKernel> {
        Ok(IngressKernel {
            id: node.id.clone(),
            inputs: node.inputs.clone(),
            step: IngressStep::compile(&node.op, &node.attrs)?,
        })
    }

    fn run(&self, df: &mut DataFrame) -> Result<()> {
        let cols: Vec<&Column> = self
            .inputs
            .iter()
            .map(|n| df.column(n))
            .collect::<Result<_>>()?;
        let out = self.step.run(&cols)?;
        df.set_column(self.id.clone(), out)
    }
}

// ---------------------------------------------------------------------------
// graph kernels

#[derive(Debug, Clone, Copy)]
enum Agg {
    Sum,
    Min,
    Max,
    Mean,
}

#[derive(Debug, Clone, Copy)]
enum BoolKind {
    And,
    Or,
    Xor,
}

#[derive(Debug, Clone, Copy)]
enum ListAggKind {
    Sum,
    Mean,
    Min,
    Max,
}

/// One pre-parsed lane of a multi-output `multi_bucketize` node.
enum LaneStep {
    Bucket { remap: Vec<i64>, width: Option<usize> },
    Compare { op: CmpOp, value: f64, width: Option<usize> },
    BucketCompare { remap: Vec<i64>, op: CmpOp, value: f64, width: Option<usize> },
}

/// Typed, fully pre-parsed kernel body. Every arm mirrors the matching
/// `eval_node` / `eval_multi` arm expression for expression.
enum Step {
    Identity,
    ToF32,
    ToI64,
    Unary(UnaryOp),
    Affine(Vec<UnaryOp>),
    Binary(BinOp),
    Bucketize(Vec<f64>),
    /// Single-output `multi_bucketize` (PR 2 ladder fusion).
    BucketCompare { splits: Vec<f64>, op: CmpOp, value: f64 },
    /// Multi-output `multi_bucketize` with named lanes (PR 3).
    Lanes { splits: Vec<f64>, lanes: Vec<LaneStep> },
    ColumnsAgg(Agg),
    DatePart(ops::date::DatePart),
    SubI64,
    AddScalarI64(i64),
    FloordivScalarI64(i64),
    Compare(CmpOp),
    CompareScalar { op: CmpOp, value: f64 },
    EqHash(i64),
    BoolOp(BoolKind),
    Not,
    Select,
    SelectCmp { op: CmpOp, value: f64 },
    IsNan,
    Assemble,
    VectorAt(usize),
    ListAgg(ListAggKind),
    ListLen,
    ElementAt(i64),
    SliceList { start: usize, len: usize },
    HashBucket(i64),
    BloomEncode { k: usize, bins: i64 },
    VocabLookup {
        hashes: Vec<i64>,
        ranks: Vec<i64>,
        num_oov: i64,
        base: i64,
        mask_hash: Option<i64>,
    },
    OneHot { hashes: Vec<i64>, ranks: Vec<i64>, num_oov: usize, drop_unseen: bool },
    ScaleVec { scale: Vec<f64>, shift: Vec<f64> },
    Impute { fill: f64, mask: Option<f64> },
    Cosine,
    Haversine,
}

impl Step {
    /// Parse one single-output node — same dispatch order and attr keys
    /// as `eval_node`, so anything it rejects the oracle would reject
    /// (or the oracle handles and we must too).
    fn compile(node: &SpecNode) -> Result<Step> {
        let a = &node.attrs;
        let unary_op: Option<UnaryOp> = match node.op.as_str() {
            "log" => Some(UnaryOp::Log { base: a.opt_f64("base") }),
            "log1p" => Some(UnaryOp::Log1p),
            "exp" => Some(UnaryOp::Exp),
            "sqrt" => Some(UnaryOp::Sqrt),
            "abs" => Some(UnaryOp::Abs),
            "neg" => Some(UnaryOp::Neg),
            "reciprocal" => Some(UnaryOp::Reciprocal),
            "round" => Some(UnaryOp::Round),
            "floor" => Some(UnaryOp::Floor),
            "ceil" => Some(UnaryOp::Ceil),
            "sin" => Some(UnaryOp::Sin),
            "cos" => Some(UnaryOp::Cos),
            "tanh" => Some(UnaryOp::Tanh),
            "sigmoid" => Some(UnaryOp::Sigmoid),
            "clip" => Some(UnaryOp::Clip { min: a.opt_f64("min"), max: a.opt_f64("max") }),
            "pow_scalar" => Some(UnaryOp::PowScalar { p: a.req_f64("p")? }),
            "add_scalar" => Some(UnaryOp::AddScalar { c: a.req_f64("c")? }),
            "sub_scalar" => Some(UnaryOp::SubScalar { c: a.req_f64("c")? }),
            "mul_scalar" => Some(UnaryOp::MulScalar { c: a.req_f64("c")? }),
            "div_scalar" => Some(UnaryOp::DivScalar { c: a.req_f64("c")? }),
            "scale_shift" => Some(UnaryOp::ScaleShift {
                scale: a.req_f64("scale")?,
                shift: a.req_f64("shift")?,
            }),
            _ => None,
        };
        if let Some(op) = unary_op {
            return Ok(Step::Unary(op));
        }
        if node.op == "affine" {
            let steps: Vec<UnaryOp> = a
                .req_array("steps")?
                .iter()
                .map(|s| {
                    Ok(match s.req_str("op")? {
                        "add_scalar" => UnaryOp::AddScalar { c: s.req_f64("c")? },
                        "sub_scalar" => UnaryOp::SubScalar { c: s.req_f64("c")? },
                        "mul_scalar" => UnaryOp::MulScalar { c: s.req_f64("c")? },
                        "div_scalar" => UnaryOp::DivScalar { c: s.req_f64("c")? },
                        "scale_shift" => UnaryOp::ScaleShift {
                            scale: s.req_f64("scale")?,
                            shift: s.req_f64("shift")?,
                        },
                        other => {
                            return Err(KamaeError::Unsupported(format!("affine step: {other}")))
                        }
                    })
                })
                .collect::<Result<_>>()?;
            return Ok(Step::Affine(steps));
        }
        if let Ok(op) = BinOp::from_name(&node.op) {
            return Ok(Step::Binary(op));
        }
        Ok(match node.op.as_str() {
            "identity" => Step::Identity,
            "to_f32" => Step::ToF32,
            "to_i64" => Step::ToI64,
            "bucketize" => Step::Bucketize(attr_f64_array(a, "splits")?),
            "columns_agg" => Step::ColumnsAgg(match a.req_str("agg")? {
                "min" => Agg::Min,
                "max" => Agg::Max,
                "mean" => Agg::Mean,
                _ => Agg::Sum,
            }),
            "date_part" => Step::DatePart(ops::date::DatePart::from_name(a.req_str("part")?)?),
            "sub_i64" => Step::SubI64,
            "add_scalar_i64" => Step::AddScalarI64(a.req_i64("c")?),
            "floordiv_scalar_i64" => Step::FloordivScalarI64(a.req_i64("c")?),
            "compare" => Step::Compare(CmpOp::from_name(a.req_str("op")?)?),
            "compare_scalar" => Step::CompareScalar {
                op: CmpOp::from_name(a.req_str("op")?)?,
                value: a.req_f64("value")?,
            },
            "eq_hash" => Step::EqHash(a.req_i64("value_hash")?),
            "bool_op" => Step::BoolOp(match a.req_str("op")? {
                "and" => BoolKind::And,
                "or" => BoolKind::Or,
                _ => BoolKind::Xor,
            }),
            "not" => Step::Not,
            "select" => Step::Select,
            "select_cmp" => Step::SelectCmp {
                op: CmpOp::from_name(a.req_str("op")?)?,
                value: a.req_f64("value")?,
            },
            "multi_bucketize" => Step::BucketCompare {
                splits: attr_f64_array(a, "splits")?,
                op: CmpOp::from_name(a.req_str("op")?)?,
                value: a.req_f64("value")?,
            },
            "is_nan" => Step::IsNan,
            "assemble" => Step::Assemble,
            "vector_at" => Step::VectorAt(a.req_i64("index")? as usize),
            "list_sum" => Step::ListAgg(ListAggKind::Sum),
            "list_mean" => Step::ListAgg(ListAggKind::Mean),
            "list_min" => Step::ListAgg(ListAggKind::Min),
            "list_max" => Step::ListAgg(ListAggKind::Max),
            "list_len" => Step::ListLen,
            "element_at" => Step::ElementAt(a.req_i64("index")?),
            "slice_list" => Step::SliceList {
                start: a.req_i64("start")? as usize,
                len: a.req_i64("len")? as usize,
            },
            "hash_bucket" => Step::HashBucket(a.req_i64("num_bins")?),
            "bloom_encode" => Step::BloomEncode {
                k: a.req_i64("num_hashes")? as usize,
                bins: a.req_i64("num_bins")?,
            },
            "vocab_lookup" => Step::VocabLookup {
                hashes: attr_i64_array(a, "vocab_hashes")?,
                ranks: attr_i64_array(a, "vocab_ranks")?,
                num_oov: a.req_i64("num_oov")?,
                base: a.req_i64("base")?,
                mask_hash: a.opt_i64("mask_hash"),
            },
            "one_hot" => Step::OneHot {
                hashes: attr_i64_array(a, "vocab_hashes")?,
                ranks: attr_i64_array(a, "vocab_ranks")?,
                num_oov: a.req_i64("num_oov")? as usize,
                drop_unseen: a.opt_bool("drop_unseen").unwrap_or(false),
            },
            "scale_vec" => Step::ScaleVec {
                scale: attr_f64_array(a, "scale")?,
                shift: attr_f64_array(a, "shift")?,
            },
            "impute" => Step::Impute { fill: a.req_f64("fill")?, mask: a.opt_f64("mask_value") },
            "cosine_similarity" => Step::Cosine,
            "haversine" => Step::Haversine,
            other => return Err(KamaeError::Unsupported(format!("graph op: {other}"))),
        })
    }

    /// Parse a multi-output node (lanes declared) — `eval_multi` only
    /// handles `multi_bucketize`; lane remap tables are validated here
    /// so the hot path never re-checks them.
    fn compile_lanes(node: &SpecNode) -> Result<Step> {
        if node.op != "multi_bucketize" {
            return Err(KamaeError::Unsupported(format!(
                "multi-output graph op: {}",
                node.op
            )));
        }
        if node.inputs.is_empty() {
            return Err(KamaeError::InvalidConfig(format!(
                "multi-output node {} has no input",
                node.id
            )));
        }
        let splits = attr_f64_array(&node.attrs, "splits")?;
        let lanes = node
            .lanes
            .iter()
            .map(|lane| {
                let a = &lane.attrs;
                let remap_for = |a: &Json| -> Result<Vec<i64>> {
                    let remap = attr_i64_array(a, "remap")?;
                    if remap.len() != splits.len() + 1 {
                        return Err(KamaeError::Serde(format!(
                            "lane {}: remap table has {} entries for {} splits",
                            lane.name,
                            remap.len(),
                            splits.len()
                        )));
                    }
                    Ok(remap)
                };
                Ok(match a.req_str("kind")? {
                    "bucket" => LaneStep::Bucket { remap: remap_for(a)?, width: lane.width },
                    "compare" => LaneStep::Compare {
                        op: CmpOp::from_name(a.req_str("op")?)?,
                        value: a.req_f64("value")?,
                        width: lane.width,
                    },
                    "bucket_compare" => LaneStep::BucketCompare {
                        remap: remap_for(a)?,
                        op: CmpOp::from_name(a.req_str("op")?)?,
                        value: a.req_f64("value")?,
                        width: lane.width,
                    },
                    other => {
                        return Err(KamaeError::Unsupported(format!(
                            "multi_bucketize lane kind: {other}"
                        )))
                    }
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Step::Lanes { splits, lanes })
    }
}

/// One compiled graph node: argument and output slots plus the typed
/// body. `node` indexes `spec.nodes` so routed cone bitmasks apply
/// directly to the kernel list.
struct Kernel {
    node: usize,
    args: Vec<usize>,
    outs: Vec<usize>,
    step: Step,
}

impl Kernel {
    fn arg<'a>(&self, arena: &'a [Option<KVal>], i: usize) -> Result<&'a KVal> {
        arena[self.args[i]].as_ref().ok_or_else(|| {
            KamaeError::ColumnNotFound(format!("kernel slot {} (graph value)", self.args[i]))
        })
    }

    /// Union of the argument row-masks (advisory null propagation).
    fn arg_nulls(&self, arena: &[Option<KVal>]) -> Option<Vec<bool>> {
        let masks: Vec<Option<&[bool]>> = self
            .args
            .iter()
            .map(|&s| arena[s].as_ref().and_then(|v| v.nulls.as_deref()))
            .collect();
        union_null_masks(&masks)
    }

    fn run(&self, arena: &mut [Option<KVal>]) -> Result<()> {
        if let Step::Lanes { .. } = self.step {
            let vals = self.eval_lanes(arena)?;
            for (&slot, v) in self.outs.iter().zip(vals) {
                arena[slot] = Some(v);
            }
        } else {
            let v = self.eval_single(arena)?;
            arena[self.outs[0]] = Some(v);
        }
        Ok(())
    }

    /// Single-output body. Every arm is the matching `eval_node` arm
    /// with attr parsing hoisted to compile time — the arithmetic
    /// (including every `as f32 as f64` rounding) is verbatim.
    fn eval_single(&self, arena: &[Option<KVal>]) -> Result<KVal> {
        let nulls = self.arg_nulls(arena);
        let f = |buf: Vec<f64>, width: Option<usize>, nulls: Option<Vec<bool>>| KVal {
            buf: KBuf::F(buf),
            width,
            nulls,
        };
        let i = |buf: Vec<i64>, width: Option<usize>, nulls: Option<Vec<bool>>| KVal {
            buf: KBuf::I(buf),
            width,
            nulls,
        };
        Ok(match &self.step {
            Step::Lanes { .. } => unreachable!("lanes handled by eval_lanes"),
            Step::Identity => self.arg(arena, 0)?.clone(),
            Step::ToF32 => {
                let x = self.arg(arena, 0)?;
                f(x.as_f().into_owned(), x.width, nulls)
            }
            Step::ToI64 => {
                let x = self.arg(arena, 0)?;
                i(x.as_i().into_owned(), x.width, nulls)
            }
            Step::Unary(op) => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&v| op.apply(v as f32 as f64) as f32 as f64)
                    .collect();
                f(data, x.width, nulls)
            }
            Step::Affine(steps) => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&v| {
                        let mut y = v;
                        for op in steps {
                            y = op.apply(y as f32 as f64) as f32 as f64;
                        }
                        y
                    })
                    .collect();
                f(data, x.width, nulls)
            }
            Step::Binary(op) => {
                let (x, y) = (self.arg(arena, 0)?, self.arg(arena, 1)?);
                let (xv, yv) = (x.as_f(), y.as_f());
                let w = x.width.or(y.width);
                let data: Vec<f64> = match (x.width, y.width) {
                    (Some(wx), None) => xv
                        .iter()
                        .enumerate()
                        .map(|(k, &p)| {
                            op.apply(p as f32 as f64, yv[k / wx] as f32 as f64) as f32 as f64
                        })
                        .collect(),
                    (None, Some(wy)) => yv
                        .iter()
                        .enumerate()
                        .map(|(k, &q)| {
                            op.apply(xv[k / wy] as f32 as f64, q as f32 as f64) as f32 as f64
                        })
                        .collect(),
                    _ => {
                        if xv.len() != yv.len() {
                            return Err(KamaeError::LengthMismatch {
                                left: xv.len(),
                                right: yv.len(),
                                context: format!("graph op {}", op.spec_name()),
                            });
                        }
                        xv.iter()
                            .zip(yv.iter())
                            .map(|(&p, &q)| op.apply(p as f32 as f64, q as f32 as f64) as f32 as f64)
                            .collect()
                    }
                };
                f(data, w, nulls)
            }
            Step::Bucketize(splits) => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&v| splits.partition_point(|&s| s <= v) as i64)
                    .collect();
                i(data, x.width, nulls)
            }
            Step::BucketCompare { splits, op, value } => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&v| {
                        let bucket = splits.partition_point(|&s| s <= v) as i64;
                        op.apply_f64(bucket as f64 as f32 as f64, *value as f32 as f64) as i64
                    })
                    .collect();
                i(data, x.width, nulls)
            }
            Step::ColumnsAgg(agg) => {
                let n = self.args.len() as f64;
                let cols: Vec<Cow<[f64]>> = (0..self.args.len())
                    .map(|k| Ok(self.arg(arena, k)?.as_f()))
                    .collect::<Result<_>>()?;
                let rows = cols[0].len();
                let data = (0..rows)
                    .map(|r| {
                        let mut acc = cols[0][r];
                        for c in cols.iter().skip(1) {
                            acc = match agg {
                                Agg::Min => acc.min(c[r]),
                                Agg::Max => acc.max(c[r]),
                                _ => acc + c[r],
                            };
                        }
                        if matches!(agg, Agg::Mean) {
                            acc / n
                        } else {
                            acc
                        }
                    })
                    .collect();
                f(data, None, nulls)
            }
            Step::DatePart(part) => {
                let x = self.arg(arena, 0)?;
                let data = x.as_i().iter().map(|&d| part.extract(d)).collect();
                i(data, x.width, nulls)
            }
            Step::SubI64 => {
                let (x, y) = (self.arg(arena, 0)?, self.arg(arena, 1)?);
                let w = x.width;
                let (xv, yv) = (x.as_i(), y.as_i());
                let data = xv.iter().zip(yv.iter()).map(|(&p, &q)| p - q).collect();
                i(data, w, nulls)
            }
            Step::AddScalarI64(c) => {
                let x = self.arg(arena, 0)?;
                i(x.as_i().iter().map(|&v| v + c).collect(), x.width, nulls)
            }
            Step::FloordivScalarI64(c) => {
                let x = self.arg(arena, 0)?;
                i(
                    x.as_i().iter().map(|&v| v.div_euclid(*c)).collect(),
                    x.width,
                    nulls,
                )
            }
            Step::Compare(op) => {
                let (x, y) = (self.arg(arena, 0)?, self.arg(arena, 1)?);
                let w = x.width;
                let (xv, yv) = (x.as_f(), y.as_f());
                let data = xv
                    .iter()
                    .zip(yv.iter())
                    .map(|(&p, &q)| op.apply_f64(p as f32 as f64, q as f32 as f64) as i64)
                    .collect();
                i(data, w, nulls)
            }
            Step::CompareScalar { op, value } => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&p| op.apply_f64(p as f32 as f64, *value as f32 as f64) as i64)
                    .collect();
                i(data, x.width, nulls)
            }
            Step::EqHash(h) => {
                let x = self.arg(arena, 0)?;
                i(
                    x.as_i().iter().map(|&v| (v == *h) as i64).collect(),
                    x.width,
                    nulls,
                )
            }
            Step::BoolOp(kind) => {
                let (x, y) = (self.arg(arena, 0)?, self.arg(arena, 1)?);
                let w = x.width;
                let (xv, yv) = (x.as_i(), y.as_i());
                let data = xv
                    .iter()
                    .zip(yv.iter())
                    .map(|(&p, &q)| {
                        let (p, q) = (p != 0, q != 0);
                        (match kind {
                            BoolKind::And => p && q,
                            BoolKind::Or => p || q,
                            BoolKind::Xor => p ^ q,
                        }) as i64
                    })
                    .collect();
                i(data, w, nulls)
            }
            Step::Not => {
                let x = self.arg(arena, 0)?;
                i(
                    x.as_i().iter().map(|&v| (v == 0) as i64).collect(),
                    x.width,
                    nulls,
                )
            }
            Step::Select => {
                let c = self.arg(arena, 0)?.as_i();
                let (xa, ya) = (self.arg(arena, 1)?, self.arg(arena, 2)?);
                let w = xa.width;
                let (x, y) = (xa.as_f(), ya.as_f());
                let data = c
                    .iter()
                    .enumerate()
                    .map(|(k, &m)| if m != 0 { x[k] } else { y[k] })
                    .collect();
                f(data, w, nulls)
            }
            Step::SelectCmp { op, value } => {
                let c = self.arg(arena, 0)?.as_f();
                let (xa, ya) = (self.arg(arena, 1)?, self.arg(arena, 2)?);
                let w = xa.width;
                let (x, y) = (xa.as_f(), ya.as_f());
                let data = c
                    .iter()
                    .enumerate()
                    .map(|(k, &v)| {
                        if op.apply_f64(v as f32 as f64, *value as f32 as f64) {
                            x[k]
                        } else {
                            y[k]
                        }
                    })
                    .collect();
                f(data, w, nulls)
            }
            Step::IsNan => {
                let x = self.arg(arena, 0)?;
                i(
                    x.as_f().iter().map(|&v| v.is_nan() as i64).collect(),
                    x.width,
                    nulls,
                )
            }
            Step::Assemble => {
                let cols: Vec<Cow<[f64]>> = (0..self.args.len())
                    .map(|k| Ok(self.arg(arena, k)?.as_f()))
                    .collect::<Result<_>>()?;
                let rows = cols[0].len();
                let w = cols.len();
                let mut data = Vec::with_capacity(rows * w);
                for r in 0..rows {
                    for c in &cols {
                        data.push(c[r]);
                    }
                }
                f(data, Some(w), nulls)
            }
            Step::VectorAt(idx) => {
                let x = self.arg(arena, 0)?;
                let w = x
                    .width
                    .ok_or_else(|| KamaeError::InvalidConfig("vector_at on scalar".into()))?;
                f(x.as_f().chunks(w).map(|row| row[*idx]).collect(), None, nulls)
            }
            Step::ListAgg(kind) => {
                let x = self.arg(arena, 0)?;
                let w = x
                    .width
                    .ok_or_else(|| KamaeError::InvalidConfig("list agg on scalar".into()))?;
                let data = x
                    .as_f()
                    .chunks(w)
                    .map(|row| match kind {
                        ListAggKind::Sum => row.iter().sum(),
                        ListAggKind::Mean => row.iter().sum::<f64>() / w as f64,
                        ListAggKind::Min => row.iter().copied().fold(f64::INFINITY, f64::min),
                        ListAggKind::Max => row.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                    })
                    .collect();
                f(data, None, nulls)
            }
            Step::ListLen => {
                let x = self.arg(arena, 0)?;
                let w = x.width.unwrap_or(1) as i64;
                i(vec![w; x.rows()], None, nulls)
            }
            Step::ElementAt(idx) => {
                let x = self.arg(arena, 0)?;
                let w = x
                    .width
                    .ok_or_else(|| KamaeError::InvalidConfig("element_at on scalar".into()))?;
                let j = if *idx < 0 { w as i64 + idx } else { *idx } as usize;
                match &x.buf {
                    KBuf::F(v) => f(v.chunks(w).map(|row| row[j]).collect(), None, nulls),
                    KBuf::I(v) => i(v.chunks(w).map(|row| row[j]).collect(), None, nulls),
                }
            }
            Step::SliceList { start, len } => {
                let x = self.arg(arena, 0)?;
                let w = x
                    .width
                    .ok_or_else(|| KamaeError::InvalidConfig("slice_list on scalar".into()))?;
                let s = (*start).min(w);
                let e = (start + len).min(w);
                match &x.buf {
                    KBuf::F(v) => f(
                        v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                        Some(e - s),
                        nulls,
                    ),
                    KBuf::I(v) => i(
                        v.chunks(w).flat_map(|row| row[s..e].to_vec()).collect(),
                        Some(e - s),
                        nulls,
                    ),
                }
            }
            Step::HashBucket(bins) => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_i()
                    .iter()
                    .map(|&h| ops::hash::bucket(h, 0, *bins))
                    .collect();
                i(data, x.width, nulls)
            }
            Step::BloomEncode { k, bins } => {
                let x = self.arg(arena, 0)?;
                let xv = x.as_i();
                let mut data = Vec::with_capacity(xv.len() * k);
                for &h in xv.iter() {
                    for j in 0..*k {
                        data.push(j as i64 * bins + ops::hash::bucket(h, j, *bins));
                    }
                }
                i(data, Some(*k), nulls)
            }
            Step::VocabLookup { hashes, ranks, num_oov, base, mask_hash } => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_i()
                    .iter()
                    .map(|&h| {
                        if Some(h) == *mask_hash {
                            return 0;
                        }
                        match hashes.binary_search(&h) {
                            Ok(k) => base + num_oov + ranks[k],
                            Err(_) => base + ops::hash::bucket(h, 0, *num_oov),
                        }
                    })
                    .collect();
                i(data, x.width, nulls)
            }
            Step::OneHot { hashes, ranks, num_oov, drop_unseen } => {
                let x = self.arg(arena, 0)?;
                let xv = x.as_i();
                let depth = if *drop_unseen {
                    hashes.len()
                } else {
                    num_oov + hashes.len()
                };
                let mut data = vec![0.0f64; xv.len() * depth];
                for (k, &h) in xv.iter().enumerate() {
                    let hot = match hashes.binary_search(&h) {
                        Ok(j) => Some(if *drop_unseen {
                            ranks[j] as usize
                        } else {
                            num_oov + ranks[j] as usize
                        }),
                        Err(_) => {
                            if *drop_unseen {
                                None
                            } else {
                                Some(ops::hash::bucket(h, 0, *num_oov as i64) as usize)
                            }
                        }
                    };
                    if let Some(hpos) = hot {
                        data[k * depth + hpos] = 1.0;
                    }
                }
                f(data, Some(depth), nulls)
            }
            Step::ScaleVec { scale, shift } => {
                let x = self.arg(arena, 0)?;
                let w = x.width.unwrap_or(1);
                if scale.len() != w {
                    return Err(KamaeError::LengthMismatch {
                        left: scale.len(),
                        right: w,
                        context: "scale_vec width".into(),
                    });
                }
                let data = x
                    .as_f()
                    .iter()
                    .enumerate()
                    .map(|(k, &v)| {
                        ((v as f32) * (scale[k % w] as f32) + (shift[k % w] as f32)) as f64
                    })
                    .collect();
                f(data, x.width, nulls)
            }
            Step::Impute { fill, mask } => {
                let x = self.arg(arena, 0)?;
                let data = x
                    .as_f()
                    .iter()
                    .map(|&v| {
                        if v.is_nan() || Some(v) == *mask {
                            *fill as f32 as f64
                        } else {
                            v as f32 as f64
                        }
                    })
                    .collect();
                // impute DEFINES every value — the advisory mask clears
                f(data, x.width, None)
            }
            Step::Cosine => {
                let (xa, ya) = (self.arg(arena, 0)?, self.arg(arena, 1)?);
                let w = xa
                    .width
                    .ok_or_else(|| KamaeError::InvalidConfig("cosine on scalar".into()))?;
                let (xv, yv) = (xa.as_f(), ya.as_f());
                let data = xv
                    .chunks(w)
                    .zip(yv.chunks(w))
                    .map(|(a, b)| {
                        let dot: f64 = a
                            .iter()
                            .zip(b.iter())
                            .map(|(p, q)| (*p as f32 * *q as f32) as f64)
                            .sum();
                        let nx = a.iter().map(|p| (*p as f32 * *p as f32) as f64).sum::<f64>().sqrt();
                        let ny = b.iter().map(|q| (*q as f32 * *q as f32) as f64).sum::<f64>().sqrt();
                        if nx == 0.0 || ny == 0.0 {
                            0.0
                        } else {
                            (dot / (nx * ny)) as f32 as f64
                        }
                    })
                    .collect();
                f(data, None, nulls)
            }
            Step::Haversine => {
                let (la1, lo1, la2, lo2) = (
                    self.arg(arena, 0)?.as_f(),
                    self.arg(arena, 1)?.as_f(),
                    self.arg(arena, 2)?.as_f(),
                    self.arg(arena, 3)?.as_f(),
                );
                let data = (0..la1.len())
                    .map(|k| {
                        ops::geo::haversine_km(
                            la1[k] as f32 as f64,
                            lo1[k] as f32 as f64,
                            la2[k] as f32 as f64,
                            lo2[k] as f32 as f64,
                        ) as f32 as f64
                    })
                    .collect();
                f(data, None, nulls)
            }
        })
    }

    /// Multi-output body — mirrors `eval_multi`: ONE merged-splits
    /// binary search shared by every lane.
    fn eval_lanes(&self, arena: &[Option<KVal>]) -> Result<Vec<KVal>> {
        let Step::Lanes { splits, lanes } = &self.step else {
            unreachable!("eval_lanes on single-output kernel")
        };
        let nulls = self.arg_nulls(arena);
        let x = self.arg(arena, 0)?;
        let xs = x.as_f();
        let merged: Vec<usize> = xs
            .iter()
            .map(|&v| splits.partition_point(|&s| s <= v))
            .collect();
        Ok(lanes
            .iter()
            .map(|lane| {
                let (data, width) = match lane {
                    LaneStep::Bucket { remap, width } => {
                        (merged.iter().map(|&m| remap[m]).collect::<Vec<i64>>(), *width)
                    }
                    LaneStep::Compare { op, value, width } => (
                        xs.iter()
                            .map(|&v| op.apply_f64(v as f32 as f64, *value as f32 as f64) as i64)
                            .collect(),
                        *width,
                    ),
                    LaneStep::BucketCompare { remap, op, value, width } => (
                        merged
                            .iter()
                            .map(|&m| {
                                let bucket = remap[m];
                                op.apply_f64(bucket as f64 as f32 as f64, *value as f32 as f64)
                                    as i64
                            })
                            .collect(),
                        *width,
                    ),
                };
                KVal { buf: KBuf::I(data), width, nulls: nulls.clone() }
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// program

/// A [`GraphSpec`] compiled to slot-indexed columnar kernels.
pub(crate) struct KernelProgram {
    ingress: Vec<IngressKernel>,
    /// Graph-input column names; input `i` binds arena slot `i`.
    inputs: Vec<String>,
    kernels: Vec<Kernel>,
    /// `spec.outputs[i]` lives in arena slot `output_slots[i]`.
    output_slots: Vec<usize>,
    output_names: Vec<String>,
    slots: usize,
}

fn bind(map: &mut HashMap<String, usize>, name: &str, slot: usize) -> Result<()> {
    if map.insert(name.to_string(), slot).is_some() {
        return Err(KamaeError::InvalidConfig(format!(
            "kernel program: duplicate graph binding '{name}'"
        )));
    }
    Ok(())
}

impl KernelProgram {
    /// Compile `spec` — called once per backend load. Errors mean "this
    /// spec shape is not kernel-compilable"; the interpreter falls back
    /// to the `eval_node` oracle so request behaviour is unchanged.
    pub(crate) fn compile(spec: &GraphSpec) -> Result<KernelProgram> {
        let ingress = spec
            .ingress
            .iter()
            .map(IngressKernel::compile)
            .collect::<Result<Vec<_>>>()?;
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        let mut slots = 0usize;
        for name in &spec.graph_inputs {
            bind(&mut slot_of, name, slots)?;
            slots += 1;
        }
        let mut kernels = Vec::with_capacity(spec.nodes.len());
        for (ni, node) in spec.nodes.iter().enumerate() {
            let args = node
                .inputs
                .iter()
                .map(|input| {
                    slot_of.get(input).copied().ok_or_else(|| {
                        KamaeError::ColumnNotFound(format!("{input} (graph value)"))
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let (step, outs) = if node.lanes.is_empty() {
                let step = Step::compile(node)?;
                let slot = slots;
                slots += 1;
                bind(&mut slot_of, &node.id, slot)?;
                (step, vec![slot])
            } else {
                let step = Step::compile_lanes(node)?;
                let mut outs = Vec::with_capacity(node.lanes.len());
                for lane in &node.lanes {
                    let slot = slots;
                    slots += 1;
                    // the bare lane name and the qualified `id.lane`
                    // reference alias ONE slot — no clone for aliases
                    bind(&mut slot_of, &lane.name, slot)?;
                    bind(&mut slot_of, &node.lane_ref(&lane.name), slot)?;
                    outs.push(slot);
                }
                (step, outs)
            };
            kernels.push(Kernel { node: ni, args, outs, step });
        }
        let output_slots = spec
            .outputs
            .iter()
            .map(|o| {
                slot_of
                    .get(o)
                    .copied()
                    .ok_or_else(|| KamaeError::ColumnNotFound(format!("{o} (spec output)")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(KernelProgram {
            ingress,
            inputs: spec.graph_inputs.clone(),
            kernels,
            output_slots,
            output_names: spec.outputs.clone(),
            slots,
        })
    }

    /// Run the pre-parsed ingress kernels over `df` in place.
    pub(crate) fn apply_ingress(&self, df: &mut DataFrame) -> Result<()> {
        for k in &self.ingress {
            k.run(df)?;
        }
        Ok(())
    }

    /// Full interpretation through the kernel program — the hot-path
    /// replacement for the env-walking `SpecInterpreter::run` body.
    pub(crate) fn run(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let mut df = df.clone();
        self.apply_ingress(&mut df)?;
        let batch = df.num_rows();
        let mut arena: Vec<Option<KVal>> = vec![None; self.slots];
        for (slot, name) in self.inputs.iter().enumerate() {
            arena[slot] = Some(KVal::from_column(df.column(name)?)?);
        }
        for k in &self.kernels {
            k.run(&mut arena)?;
        }
        self.output_slots
            .iter()
            .zip(self.output_names.iter())
            .map(|(&slot, name)| {
                arena[slot]
                    .as_ref()
                    .map(|v| v.to_tensor(batch))
                    .ok_or_else(|| KamaeError::ColumnNotFound(format!("{name} (spec output)")))
            })
            .collect()
    }

    /// Variant-routed interpretation over per-group cone bitmasks (the
    /// masks `SpecInterpreter::run_routed` computes from its
    /// `ConeCache`). Same row-granularity algorithm as the oracle:
    /// nodes needed by ≥2 groups run once over the full batch, nodes
    /// needed by one group run on that group's rows only, shared values
    /// are sliced into the group arena on demand.
    pub(crate) fn run_routed(
        &self,
        df: &DataFrame,
        groups: &[RouteGroup],
        ingress_masks: &[u64],
        input_masks: &[u64],
        node_masks: &[u64],
    ) -> Result<Vec<Vec<Tensor>>> {
        // ---- ingress: shared over the full batch, exclusive per group
        let mut full_df = df.clone();
        for (k, mask) in self.ingress.iter().zip(ingress_masks.iter()) {
            if mask.count_ones() >= 2 {
                k.run(&mut full_df)?;
            }
        }
        let mut group_dfs: Vec<Option<DataFrame>> = vec![None; groups.len()];
        for (gi, g) in groups.iter().enumerate() {
            let mut gdf: Option<DataFrame> = None;
            for (k, mask) in self.ingress.iter().zip(ingress_masks.iter()) {
                if *mask == 1 << gi {
                    let gdf =
                        gdf.get_or_insert_with(|| full_df.slice(g.rows.start, g.rows.len()));
                    k.run(gdf)?;
                }
            }
            group_dfs[gi] = gdf;
        }

        // ---- graph inputs into the shared / per-group arenas
        let mut arena_full: Vec<Option<KVal>> = vec![None; self.slots];
        let mut arena_groups: Vec<Vec<Option<KVal>>> =
            (0..groups.len()).map(|_| vec![None; self.slots]).collect();
        for (slot, name) in self.inputs.iter().enumerate() {
            let m = input_masks[slot];
            if m.count_ones() >= 2 {
                arena_full[slot] = Some(KVal::from_column(full_df.column(name)?)?);
            } else if m != 0 {
                let gi = m.trailing_zeros() as usize;
                let g = &groups[gi];
                let v = match &group_dfs[gi] {
                    Some(gdf) => KVal::from_column(gdf.column(name)?)?,
                    None => KVal::from_column(
                        full_df.slice(g.rows.start, g.rows.len()).column(name)?,
                    )?,
                };
                arena_groups[gi][slot] = Some(v);
            }
        }

        // ---- kernels at row granularity
        for k in &self.kernels {
            let m = node_masks[k.node];
            if m == 0 {
                continue;
            }
            if m.count_ones() >= 2 {
                k.run(&mut arena_full)?;
            } else {
                let gi = m.trailing_zeros() as usize;
                let g = &groups[gi];
                for &slot in &k.args {
                    if arena_groups[gi][slot].is_none() {
                        if let Some(v) = &arena_full[slot] {
                            arena_groups[gi][slot] =
                                Some(v.slice_rows(g.rows.start, g.rows.len()));
                        }
                    }
                }
                k.run(&mut arena_groups[gi])?;
            }
        }

        // ---- collect each group's requested outputs
        groups
            .iter()
            .enumerate()
            .map(|(gi, g)| {
                g.outputs
                    .iter()
                    .map(|&oi| {
                        let slot = *self.output_slots.get(oi).ok_or_else(|| {
                            KamaeError::InvalidConfig(format!(
                                "route group requests output {oi} of {}",
                                self.output_slots.len()
                            ))
                        })?;
                        if let Some(v) = &arena_groups[gi][slot] {
                            return Ok(v.to_tensor(g.rows.len()));
                        }
                        arena_full[slot]
                            .as_ref()
                            .map(|v| {
                                v.slice_rows(g.rows.start, g.rows.len()).to_tensor(g.rows.len())
                            })
                            .ok_or_else(|| {
                                KamaeError::ColumnNotFound(format!(
                                    "{} (routed spec output)",
                                    self.output_names[oi]
                                ))
                            })
                    })
                    .collect()
            })
            .collect()
    }

    /// Number of compiled graph kernels (diagnostics / tests).
    pub(crate) fn kernel_count(&self) -> usize {
        self.kernels.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::export::{SpecDType, SpecInput, SpecInterpreter, SpecLane};

    fn node(id: &str, op: &str, ins: &[&str], attrs: &str, dtype: SpecDType) -> SpecNode {
        SpecNode {
            id: id.into(),
            op: op.into(),
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            attrs: Json::parse(attrs).unwrap(),
            dtype,
            width: None,
            lanes: vec![],
        }
    }

    fn two_input_spec(nodes: Vec<SpecNode>, outputs: &[&str]) -> GraphSpec {
        GraphSpec {
            name: "t".into(),
            inputs: vec![
                SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                SpecInput { name: "y".into(), dtype: DType::F64, width: None },
            ],
            ingress: vec![],
            graph_inputs: vec!["x".into(), "y".into()],
            nodes,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }

    fn sample_df() -> DataFrame {
        DataFrame::new(vec![
            (
                "x".into(),
                Column::from_f64(vec![-2.5, -1.0, 0.0, 0.3, 1.0, 2.0, f64::NAN]),
            ),
            (
                "y".into(),
                Column::from_f64(vec![7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0]),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn kernel_program_matches_oracle_on_graph_ops() {
        let nodes = vec![
            node("l", "log1p", &["x"], "{}", SpecDType::F32),
            node("s", "add", &["l", "y"], "{}", SpecDType::F32),
            node("b", "bucketize", &["x"], r#"{"splits": [-1.0, 0.0, 1.0]}"#, SpecDType::I64),
            node("c", "compare_scalar", &["b"], r#"{"op": "ge", "value": 2.0}"#, SpecDType::I64),
            node("sel", "select", &["c", "x", "y"], "{}", SpecDType::F32),
            node("im", "impute", &["x"], r#"{"fill": 0.25}"#, SpecDType::F32),
        ];
        let spec = two_input_spec(nodes, &["s", "c", "sel", "im"]);
        let df = sample_df();
        let program = KernelProgram::compile(&spec).unwrap();
        assert_eq!(program.kernel_count(), 6);
        let got = program.run(&df).unwrap();
        let want = SpecInterpreter::new_oracle(spec).run(&df).unwrap();
        crate::util::prop::tensors_bit_identical(&got, &want).unwrap();
    }

    #[test]
    fn kernel_program_matches_oracle_on_lanes() {
        let mut lanes_node = node(
            "x__lanes",
            "multi_bucketize",
            &["x"],
            r#"{"splits": [-1.0, 0.0, 0.5, 1.0]}"#,
            SpecDType::I64,
        );
        let lane = |name: &str, attrs: &str| SpecLane {
            name: name.into(),
            attrs: Json::parse(attrs).unwrap(),
            dtype: SpecDType::I64,
            width: None,
        };
        lanes_node.lanes = vec![
            lane("b1", r#"{"kind": "bucket", "remap": [0, 1, 2, 2, 3]}"#),
            lane("c1", r#"{"kind": "compare", "op": "gt", "value": 0.0}"#),
            lane(
                "f1",
                r#"{"kind": "bucket_compare", "remap": [0, 1, 2, 2, 2], "op": "ge", "value": 2.0}"#,
            ),
        ];
        let nodes = vec![
            lanes_node,
            node("n", "not", &["x__lanes.c1"], "{}", SpecDType::I64),
        ];
        let spec = two_input_spec(nodes, &["b1", "c1", "f1", "n"]);
        let df = sample_df();
        let program = KernelProgram::compile(&spec).unwrap();
        let got = program.run(&df).unwrap();
        let want = SpecInterpreter::new_oracle(spec).run(&df).unwrap();
        crate::util::prop::tensors_bit_identical(&got, &want).unwrap();
    }

    #[test]
    fn unknown_op_fails_compile_but_interpreter_falls_back() {
        let spec = two_input_spec(
            vec![node("z", "no_such_op", &["x"], "{}", SpecDType::F32)],
            &["z"],
        );
        assert!(KernelProgram::compile(&spec).is_err());
        // the interpreter keeps working (oracle path) and reports the
        // same request-time error the oracle always did
        let interp = SpecInterpreter::new(spec);
        assert!(!interp.is_compiled());
        let err = interp.run(&sample_df()).unwrap_err();
        assert!(err.to_string().contains("graph op: no_such_op"), "{err}");
    }

    #[test]
    fn null_masks_propagate_and_impute_clears() {
        let df = DataFrame::new(vec![
            (
                "x".into(),
                Column::F64(vec![1.0, 2.0, 3.0], Some(vec![false, true, false])),
            ),
            (
                "y".into(),
                Column::F64(vec![4.0, 5.0, 6.0], Some(vec![true, false, false])),
            ),
        ])
        .unwrap();
        let spec = two_input_spec(
            vec![
                node("s", "add", &["x", "y"], "{}", SpecDType::F32),
                node("im", "impute", &["s"], r#"{"fill": 0.0}"#, SpecDType::F32),
            ],
            &["s", "im"],
        );
        let program = KernelProgram::compile(&spec).unwrap();
        let mut arena: Vec<Option<KVal>> = vec![None; program.slots];
        for (slot, name) in program.inputs.iter().enumerate() {
            arena[slot] = Some(KVal::from_column(df.column(name).unwrap()).unwrap());
        }
        for k in &program.kernels {
            k.run(&mut arena).unwrap();
        }
        // slot 2 = "s": union of the input masks; slot 3 = "im": cleared
        assert_eq!(
            arena[2].as_ref().unwrap().nulls,
            Some(vec![true, true, false])
        );
        assert_eq!(arena[3].as_ref().unwrap().nulls, None);
        // values still match the oracle exactly (masks are advisory)
        let got = program.run(&df).unwrap();
        let want = SpecInterpreter::new_oracle(spec).run(&df).unwrap();
        crate::util::prop::tensors_bit_identical(&got, &want).unwrap();
    }
}
