#!/usr/bin/env python3
"""Compare bench trajectory artifacts and fail on throughput regressions.

The nightly `bench-trajectory` job runs `make bench-all`, which appends
one run per bench to `BENCH_<name>.json` (a JSON array of runs; each run
is ``{"bench": ..., "quick": ..., "records": [...]}``). This tool diffs
the freshly produced files against the previous night's artifact and
exits non-zero when any gated throughput metric dropped by more than the
allowed regression.

Gated metrics are the numeric record fields whose key ends in ``_rps``
or starts with ``throughput`` — the same naming every gated bench uses
for its req/s numbers. Latency fields (``*_ns``), counts and cost fields
are reported for context only, never gated (they scale with workload
knobs, not just machine speed).

Only the LATEST non-quick run in each file is compared: quick
(``"quick": true``) runs are the `make bench-smoke` flavour with reduced
workloads — their numbers are not comparable across nights. The CI
smoke job redirects its trajectory output to a temp dir via
``KAMAE_BENCH_DIR`` precisely so quick runs never land in the nightly
artifact; finding one in --current therefore fails the run (it means
that redirect regressed).

Usage:
    python3 tools/bench_compare.py --current . --previous prev-artifact/

Exit codes: 0 ok (including "no previous artifact yet"), 1 regression or
malformed input.

Override knob: ``--max-regression <pct>`` (default 10), or the
``KAMAE_BENCH_COMPARE_MAX_REGRESSION`` env var — e.g. set it to 25 on a
known-noisy runner, or to a huge value with an accompanying commit
message to deliberately accept a regression. The env var loses to an
explicit flag.
"""

import argparse
import glob
import json
import os
import sys

DEFAULT_MAX_REGRESSION_PCT = 10.0


def is_gated_metric(key, value):
    """Numeric throughput field? (bools are ints in Python — exclude.)"""
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        return False
    return key.endswith("_rps") or key.startswith("throughput")


def load_runs(path):
    with open(path) as f:
        runs = json.load(f)
    if not isinstance(runs, list):
        raise ValueError(f"{path}: expected a JSON array of runs")
    return runs


def latest_full_run(runs):
    """Last run whose `quick` field is not true, or None."""
    for run in reversed(runs):
        if isinstance(run, dict) and run.get("quick") is not True:
            return run
    return None


def record_label(record, index):
    """Stable-ish label for one record inside a run."""
    for key in ("name", "mode", "spec"):
        v = record.get(key)
        if isinstance(v, str) and v:
            return v
    return f"record[{index}]"


def gated_metrics(run):
    """{(record_label, key): value} for every gated metric in a run."""
    out = {}
    for i, record in enumerate(run.get("records", [])):
        if not isinstance(record, dict):
            continue
        label = record_label(record, i)
        for key, value in record.items():
            if is_gated_metric(key, value):
                out[(label, key)] = float(value)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, help="dir with fresh BENCH_*.json files")
    ap.add_argument("--previous", required=True, help="dir with the prior artifact's BENCH_*.json files")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=None,
        help=f"allowed throughput drop in percent (default {DEFAULT_MAX_REGRESSION_PCT}, "
        "env KAMAE_BENCH_COMPARE_MAX_REGRESSION)",
    )
    args = ap.parse_args()

    max_regression = args.max_regression
    if max_regression is None:
        env = os.environ.get("KAMAE_BENCH_COMPARE_MAX_REGRESSION", "")
        try:
            max_regression = float(env) if env else DEFAULT_MAX_REGRESSION_PCT
        except ValueError:
            print(f"bad KAMAE_BENCH_COMPARE_MAX_REGRESSION={env!r}", file=sys.stderr)
            return 1

    current_files = sorted(glob.glob(os.path.join(args.current, "BENCH_*.json")))
    if not current_files:
        print(f"no BENCH_*.json files in --current {args.current}", file=sys.stderr)
        return 1
    if not os.path.isdir(args.previous) or not glob.glob(
        os.path.join(args.previous, "BENCH_*.json")
    ):
        # first nightly run (or artifact expired): nothing to diff against
        print(f"no previous artifact in {args.previous!r}; skipping comparison")
        return 0

    failures = []
    compared = 0
    for cur_path in current_files:
        bench = os.path.basename(cur_path)
        try:
            cur_runs = load_runs(cur_path)
        except (ValueError, json.JSONDecodeError) as e:
            failures.append(f"{bench}: unreadable current file: {e}")
            continue

        # smoke-exclusion assert: quick runs must never reach the
        # nightly artifact (bench-smoke writes to a KAMAE_BENCH_DIR
        # temp dir; a quick run here means that redirect regressed)
        quick_runs = sum(
            1 for r in cur_runs if isinstance(r, dict) and r.get("quick") is True
        )
        if quick_runs:
            failures.append(
                f"{bench}: {quick_runs} quick (smoke) run(s) in the nightly artifact — "
                "bench-smoke must write to a KAMAE_BENCH_DIR temp dir, not the repo"
            )
            continue

        # a bench with no previous counterpart is NEW — everything about
        # it is informational on its first nightly (a freshly landed
        # bench must not fail the run it lands in; e.g.
        # BENCH_ingress_validation.json is compared only once the night
        # after it first appears)
        prev_path = os.path.join(args.previous, bench)
        is_new_bench = not os.path.exists(prev_path)

        cur = latest_full_run(cur_runs)
        if cur is None:
            if is_new_bench:
                print(f"{bench}: new bench, no full run yet; informational only")
            else:
                failures.append(f"{bench}: no full (non-quick) run in current file")
            continue

        if is_new_bench:
            print(f"{bench}: new bench (no previous file); skipping")
            continue
        try:
            prev = latest_full_run(load_runs(prev_path))
        except (ValueError, json.JSONDecodeError) as e:
            print(f"{bench}: unreadable previous file ({e}); skipping")
            continue
        if prev is None:
            print(f"{bench}: previous file has no full run; skipping")
            continue

        cur_metrics = gated_metrics(cur)
        prev_metrics = gated_metrics(prev)
        for (label, key), prev_value in sorted(prev_metrics.items()):
            cur_value = cur_metrics.get((label, key))
            if cur_value is None:
                # a renamed/removed metric is not a perf regression;
                # note it so silent gate erosion is at least visible
                print(f"{bench} {label}.{key}: metric gone from current run")
                continue
            if prev_value <= 0:
                continue
            delta_pct = 100.0 * (cur_value / prev_value - 1.0)
            verdict = "ok"
            if delta_pct < -max_regression:
                verdict = "REGRESSION"
                failures.append(
                    f"{bench} {label}.{key}: {prev_value:.0f} -> {cur_value:.0f} "
                    f"({delta_pct:+.1f}%, allowed -{max_regression:g}%)"
                )
            print(
                f"{bench} {label}.{key}: {prev_value:.0f} -> {cur_value:.0f} "
                f"({delta_pct:+.1f}%) {verdict}"
            )
            compared += 1
        # metrics that only exist in the current run (a bench grew a new
        # gated number) have no baseline yet — log, never fail
        for (label, key), cur_value in sorted(cur_metrics.items()):
            if (label, key) not in prev_metrics:
                print(
                    f"{bench} {label}.{key}: {cur_value:.0f} "
                    "(new metric, no previous value; informational only)"
                )

    print(f"\ncompared {compared} gated metric(s), {len(failures)} failure(s)")
    if failures:
        print("", file=sys.stderr)
        for f in failures:
            print(f"BENCH COMPARE FAILURE: {f}", file=sys.stderr)
        print(
            "\noverride: --max-regression <pct> or KAMAE_BENCH_COMPARE_MAX_REGRESSION "
            "(see examples/bench_compare.md)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
